"""Bounded structured event tracing for the fleet DES.

:class:`EventTrace` is a fixed-capacity ring buffer of typed events stored
columnar (int8 kind / float64 time / int16 pool / int64 request id / float64
value) — emitting an event is five array stores and one integer increment,
no allocation, so tracing can stay on during large vectorized runs. When
the ring wraps, the oldest events are overwritten and counted in
``dropped`` (observability must never grow without bound).

Event kinds (see :mod:`repro.obs` for field semantics):

``arrival``         a request reached the fleet (router track)
``dispatch``        the router chose a pool (value = estimated L_total)
``admit``           an instance moved the request queue → active slots
``preempt``         vLLM-style preemption-by-recompute of the request
``truncate``        the request hit C_max mid-generation
``reject``          the request could never fit its pool (hard reject)
``spill``           load-aware spillover redirected the request
``threshold_move``  the adaptive controller moved boundary ``request_id``
                    (value = new B_k; router track)
``calib_sync``      a calibration feedback sync (value = observations
                    folded into the EMA; router track)
``fail``            a fault fired on instance ``request_id`` of the pool
                    (value = in-flight sequences lost for crash/OOM, or
                    the slowdown factor for straggler onset)
``recover``         instance ``request_id`` of the pool returned to
                    service (crash recovery, warm-up end, or slowdown end)
``retry``           a lost request was re-dispatched (value = attempt
                    number; pool = the pool chosen on re-route)
``timeout``         a request exceeded its deadline and was dropped
                    (router track)
``shed``            a request exhausted its retry budget and was dropped
                    (router track)

Exports: ``to_jsonl()`` (one JSON object per line) and
``to_chrome_trace()`` — the Chrome trace-event JSON format, with one
thread (track) per pool plus a ``router`` track, so a run opens directly
in Perfetto / ``chrome://tracing``.
"""

from __future__ import annotations

import json

import numpy as np

#: Typed event kinds (int8 codes stored in the ring). Append-only: codes
#: 0–8 predate fault injection and must stay stable for old traces.
(
    ARRIVAL,
    DISPATCH,
    ADMIT,
    PREEMPT,
    TRUNCATE,
    REJECT,
    SPILL,
    THRESHOLD_MOVE,
    CALIB_SYNC,
    FAIL,
    RECOVER,
    RETRY,
    TIMEOUT,
    SHED,
) = range(14)

EVENT_NAMES = (
    "arrival",
    "dispatch",
    "admit",
    "preempt",
    "truncate",
    "reject",
    "spill",
    "threshold_move",
    "calib_sync",
    "fail",
    "recover",
    "retry",
    "timeout",
    "shed",
)

#: Pseudo-pool id for fleet/router-level events (arrival, threshold moves,
#: calibration syncs); rendered as its own track in the Chrome trace.
ROUTER_TRACK = -1


class EventTrace:
    """Fixed-capacity ring buffer of typed simulator events."""

    def __init__(self, capacity: int = 1 << 16, pool_names=()) -> None:
        if capacity <= 0:
            raise ValueError(f"capacity must be positive: {capacity}")
        # Round up to a power of two so the ring index is a mask, not a mod.
        cap = 1 << (int(capacity) - 1).bit_length()
        self.capacity = cap
        self._mask = cap - 1
        self._n = 0
        self.pool_names = [str(p) for p in pool_names]
        self.kind = np.zeros(cap, dtype=np.int8)
        self.t = np.zeros(cap, dtype=np.float64)
        self.pool = np.zeros(cap, dtype=np.int16)
        self.request_id = np.zeros(cap, dtype=np.int64)
        self.value = np.zeros(cap, dtype=np.float64)

    # -- hot path ------------------------------------------------------------
    def emit(
        self,
        kind: int,
        t: float,
        pool: int,
        request_id: int,
        value: float = 0.0,
    ) -> None:
        i = self._n & self._mask
        self.kind[i] = kind
        self.t[i] = t
        self.pool[i] = pool
        self.request_id[i] = request_id
        self.value[i] = value
        self._n += 1

    # -- introspection -------------------------------------------------------
    def __len__(self) -> int:
        return min(self._n, self.capacity)

    @property
    def emitted(self) -> int:
        """Total events ever emitted (retained + dropped)."""
        return self._n

    @property
    def dropped(self) -> int:
        """Events overwritten by ring wrap-around (oldest first)."""
        return max(0, self._n - self.capacity)

    def _order(self) -> np.ndarray:
        """Ring indices of the retained events, oldest → newest."""
        n = len(self)
        start = self._n - n
        return (start + np.arange(n)) & self._mask

    def track_name(self, pool: int) -> str:
        if 0 <= pool < len(self.pool_names):
            return self.pool_names[pool]
        return "router"

    def events(self) -> list[dict]:
        """Retained events as dicts, chronological (emission) order."""
        idx = self._order()
        return [
            {
                "kind": EVENT_NAMES[int(self.kind[i])],
                "t": float(self.t[i]),
                "pool": self.track_name(int(self.pool[i])),
                "request_id": int(self.request_id[i]),
                "value": float(self.value[i]),
            }
            for i in idx
        ]

    # -- exports -------------------------------------------------------------
    def to_jsonl(self) -> str:
        """One JSON object per line; first line is a header record."""
        header = {
            "schema": "repro.obs/events-v1",
            "pools": list(self.pool_names),
            "emitted": self.emitted,
            "dropped": self.dropped,
        }
        lines = [json.dumps(header)]
        lines.extend(json.dumps(e) for e in self.events())
        return "\n".join(lines) + "\n"

    def to_chrome_trace(self) -> str:
        """Chrome trace-event JSON (Perfetto-loadable), one pool per track.

        Times are exported in microseconds (``ts`` is µs in the trace-event
        spec); every event is an instant ('i') on its pool's thread, with
        ``request_id``/``value`` preserved under ``args``.
        """
        tracks = list(self.pool_names) + ["router"]
        router_tid = len(self.pool_names)
        trace_events: list[dict] = [
            {
                "name": "process_name",
                "ph": "M",
                "pid": 0,
                "tid": 0,
                "args": {"name": "fleet-sim"},
            }
        ]
        for tid, name in enumerate(tracks):
            trace_events.append(
                {
                    "name": "thread_name",
                    "ph": "M",
                    "pid": 0,
                    "tid": tid,
                    "args": {"name": name},
                }
            )
        for i in self._order():
            pool = int(self.pool[i])
            tid = pool if 0 <= pool < router_tid else router_tid
            trace_events.append(
                {
                    "name": EVENT_NAMES[int(self.kind[i])],
                    "ph": "i",
                    "s": "t",
                    "ts": float(self.t[i]) * 1e6,
                    "pid": 0,
                    "tid": tid,
                    "args": {
                        "request_id": int(self.request_id[i]),
                        "value": float(self.value[i]),
                    },
                }
            )
        return json.dumps({"traceEvents": trace_events, "displayTimeUnit": "ms"})
