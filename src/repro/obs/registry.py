"""O(1), allocation-free metrics primitives for the fleet telemetry layer.

A :class:`MetricsRegistry` owns one preallocated float64 slab; every counter
and gauge is an index into it, so the hot-path mutation is a single
``slab[i] += v`` / ``slab[i] = v`` with no per-observation allocation.
Histograms use *fixed* bucket edges declared at registration time — one
``bisect`` plus one integer increment per scalar observation, one
``searchsorted`` + ``bincount`` fold for bulk observations.

Registration (``counter()``/``gauge()``/``histogram()``) is the only place
that allocates (the slab doubles when full); it happens at telemetry setup,
never inside the simulation loop. The registry is deliberately ignorant of
the simulator — the fleet telemetry layer (:mod:`repro.obs.timeseries`)
decides what to register and when to write.
"""

from __future__ import annotations

from bisect import bisect_right
from typing import Sequence

import numpy as np


class Counter:
    """Monotone accumulator: one slab slot, ``add()`` is ``slab[i] += v``."""

    __slots__ = ("_reg", "_i", "name")

    def __init__(self, reg: "MetricsRegistry", i: int, name: str) -> None:
        self._reg = reg
        self._i = i
        self.name = name

    def add(self, v: float = 1.0) -> None:
        self._reg._slab[self._i] += v

    inc = add

    @property
    def value(self) -> float:
        return float(self._reg._slab[self._i])


class Gauge:
    """Last-write-wins sample: one slab slot, ``set()`` is ``slab[i] = v``."""

    __slots__ = ("_reg", "_i", "name")

    def __init__(self, reg: "MetricsRegistry", i: int, name: str) -> None:
        self._reg = reg
        self._i = i
        self.name = name

    def set(self, v: float) -> None:
        self._reg._slab[self._i] = v

    @property
    def value(self) -> float:
        return float(self._reg._slab[self._i])


class Histogram:
    """Fixed-bucket histogram: ``len(edges)+1`` counts, edges ascending.

    Bucket ``j`` counts observations in ``(edges[j-1], edges[j]]``; bucket
    ``len(edges)`` is the overflow. Edges are frozen at registration — no
    rebinning, no allocation on ``observe``.
    """

    __slots__ = ("name", "edges", "counts", "_edges_list")

    def __init__(self, name: str, edges: Sequence[float]) -> None:
        e = [float(x) for x in edges]
        if not e or any(b <= a for a, b in zip(e, e[1:])):
            raise ValueError(f"histogram edges must be strictly increasing: {e}")
        self.name = name
        self.edges = np.asarray(e, dtype=np.float64)
        self._edges_list = e  # plain list: bisect beats np.searchsorted 1-at-a-time
        self.counts = np.zeros(len(e) + 1, dtype=np.int64)

    def observe(self, v: float) -> None:
        self.counts[bisect_right(self._edges_list, v)] += 1

    def observe_many(self, values) -> None:
        idx = np.searchsorted(self.edges, np.asarray(values), side="right")
        self.counts += np.bincount(idx, minlength=len(self.counts))

    @property
    def total(self) -> int:
        return int(self.counts.sum())

    def snapshot(self) -> dict:
        return {
            "edges": [float(x) for x in self.edges],
            "counts": [int(c) for c in self.counts],
        }


class MetricsRegistry:
    """Named counters/gauges/histograms over one preallocated value slab."""

    def __init__(self, capacity: int = 64) -> None:
        self._slab = np.zeros(max(1, capacity), dtype=np.float64)
        self._index: dict[str, int] = {}
        self._kinds: dict[str, str] = {}
        self._histograms: dict[str, Histogram] = {}

    def _alloc(self, name: str, kind: str) -> int:
        if name in self._kinds:
            raise ValueError(f"metric {name!r} already registered")
        i = len(self._index)
        if i >= len(self._slab):
            self._slab = np.concatenate([self._slab, np.zeros_like(self._slab)])
        self._index[name] = i
        self._kinds[name] = kind
        return i

    def counter(self, name: str) -> Counter:
        return Counter(self, self._alloc(name, "counter"), name)

    def gauge(self, name: str) -> Gauge:
        return Gauge(self, self._alloc(name, "gauge"), name)

    def histogram(self, name: str, edges: Sequence[float]) -> Histogram:
        if name in self._kinds:
            raise ValueError(f"metric {name!r} already registered")
        self._kinds[name] = "histogram"
        h = Histogram(name, edges)
        self._histograms[name] = h
        return h

    def value(self, name: str) -> float:
        return float(self._slab[self._index[name]])

    def values(self) -> dict[str, float]:
        return {n: float(self._slab[i]) for n, i in self._index.items()}

    def snapshot(self) -> dict:
        """JSON-ready view: scalar values plus histogram edge/count pairs."""
        return {
            "values": self.values(),
            "kinds": dict(self._kinds),
            "histograms": {n: h.snapshot() for n, h in self._histograms.items()},
        }
