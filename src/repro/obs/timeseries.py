"""Windowed time-series sampling for the fleet simulator.

:class:`FleetTelemetry` is the piece the fleet layer talks to: the fleet
calls :meth:`FleetTelemetry.sample` once per control window (the same
request-count windows the adaptive controller acts on — see
:mod:`repro.obs` for the window semantics) and the sampler appends one row
to every column: per-pool queue depth, slot/KV occupancy,
preemption/rejection/truncation deltas, the live threshold vector, fleet
spill deltas, and — when the trace columns were attached via
:meth:`set_trace` — per-category calibration error and live EMA ratios.

Sampling is O(pools + categories) per window and touches no per-request
state, so it is *off* the simulation hot path by construction; the hot
path's only telemetry cost is the ``tracer is not None`` guards in the
engines, which a disabled run never takes.

Exports: :meth:`to_dict` / :meth:`to_json` (schema
``repro.obs/telemetry-v1``, or ``repro.obs/telemetry-v2`` when a fault
runtime is attached — v2 adds fleet ``retries``/``timeouts`` deltas plus
per-pool ``down.<pool>`` / ``failures.<pool>`` / ``breaker_open.<pool>``
health columns) and :meth:`to_csv` (one row per window, flat dotted
column names).
"""

from __future__ import annotations

import dataclasses
import io
import json
import math
from typing import Optional, Sequence

import numpy as np

from repro.obs.events import CALIB_SYNC, ROUTER_TRACK, EventTrace
from repro.obs.registry import MetricsRegistry

#: Fixed bucket edges (tokens) for the estimated-budget histogram — powers
#: of two spanning the practical L_total range of the paper's topologies.
BUDGET_EDGES = tuple(float(1 << p) for p in range(8, 18))


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Knobs for :class:`FleetTelemetry` (all optional).

    ``window``
        Sampling window in dispatched requests. ``None`` → use the fleet's
        ``control_window`` (so samples land exactly on controller
        boundaries, which is what the equivalence suite locks).
    ``events``
        Also record the typed event ring (:class:`~repro.obs.events.EventTrace`).
    ``event_capacity``
        Ring capacity (rounded up to a power of two); oldest events are
        overwritten past it.
    """

    window: Optional[int] = None
    events: bool = False
    event_capacity: int = 1 << 16


class FleetTelemetry:
    """Per-window observable series for one fleet run.

    Built by ``FleetSim`` when telemetry is requested; ``pools`` are the
    pool sims in budget order (the controller's frame), ``router`` is the
    fleet's :class:`~repro.core.router.TokenBudgetRouter` (``None`` for the
    routerless single-pool baseline).
    """

    def __init__(
        self,
        config: TelemetryConfig,
        pool_names: Sequence[str],
        pools: Sequence,
        router=None,
        health=None,
    ) -> None:
        self.config = config
        self.pool_names = list(pool_names)
        self._pools = list(pools)
        self._router = router
        self._health = health
        self.events: Optional[EventTrace] = (
            EventTrace(config.event_capacity, pool_names=self.pool_names)
            if config.events
            else None
        )

        # -- registry: live gauges/counters, updated once per window ---------
        self.registry = MetricsRegistry()
        reg = self.registry
        self._g_queue = [reg.gauge(f"queue_depth.{p}") for p in self.pool_names]
        self._g_active = [reg.gauge(f"active.{p}") for p in self.pool_names]
        self._g_kv = [reg.gauge(f"kv_frac.{p}") for p in self.pool_names]
        self._c_pre = [reg.counter(f"preemptions.{p}") for p in self.pool_names]
        self._c_rej = [reg.counter(f"rejections.{p}") for p in self.pool_names]
        self._c_trunc = [reg.counter(f"truncations.{p}") for p in self.pool_names]
        self._c_spills = reg.counter("spills")
        self.budget_hist = reg.histogram("budget_est_tokens", BUDGET_EDGES)

        # -- windowed delta baselines -----------------------------------------
        p = len(self._pools)
        self._prev_pre = [0] * p
        self._prev_rej = [0] * p
        self._prev_trunc = [0] * p
        self._prev_spills = 0
        self._prev_calib = 0

        # -- trace columns for calibration-error sampling ---------------------
        self._byte_len: Optional[np.ndarray] = None
        self._category: Optional[np.ndarray] = None
        self._true_input: Optional[np.ndarray] = None
        self._mot: Optional[np.ndarray] = None

        # -- the series -------------------------------------------------------
        self.columns: dict[str, list] = {"t_req": [], "t_sim": [], "spills": []}
        if router is not None:
            for k in range(len(router.pools) - 1):
                self.columns[f"threshold.{k}"] = []
        for name in self.pool_names:
            for col in (
                "queue_depth",
                "active",
                "slot_frac",
                "kv_frac",
                "preemptions",
                "rejections",
                "truncations",
            ):
                self.columns[f"{col}.{name}"] = []
        self._num_categories = 0
        if router is not None:
            self._num_categories = router.calibrator.num_categories
            for k in range(self._num_categories):
                self.columns[f"calib_err.cat{k}"] = []
                self.columns[f"ema_ratio.cat{k}"] = []
        if health is not None:
            self.columns["retries"] = []
            self.columns["timeouts"] = []
            for name in self.pool_names:
                self.columns[f"down.{name}"] = []
                self.columns[f"failures.{name}"] = []
                self.columns[f"breaker_open.{name}"] = []
            self._prev_retries = 0
            self._prev_timeouts = 0
            self._prev_fail = [0] * len(self._pools)

    # -- trace attachment ------------------------------------------------------
    def set_trace(
        self,
        byte_len: np.ndarray,
        category: np.ndarray,
        true_input: np.ndarray,
        max_output_tokens: Optional[np.ndarray] = None,
    ) -> None:
        """Attach the arrival-ordered trace columns.

        Windows index these arrays by dispatch position, so the order must
        match the order requests are dispatched (both backends dispatch in
        arrival order). Enables the ``calib_err.*`` series and the budget
        histogram; without a trace those stay NaN/empty.
        """
        self._byte_len = np.asarray(byte_len)
        self._category = np.asarray(category)
        self._true_input = np.asarray(true_input)
        if max_output_tokens is not None:
            self._mot = np.asarray(max_output_tokens)

    # -- the per-window sample -------------------------------------------------
    def sample(self, t_req: int, now: float, lo: int, hi: int) -> None:
        """Append one row covering dispatch positions ``[lo, hi)``.

        ``t_req`` is the dispatched-request count at the window boundary
        (== ``hi``), ``now`` the sim time of the sample. Counter columns are
        windowed deltas; gauges are read live at the boundary.
        """
        cols = self.columns
        cols["t_req"].append(int(t_req))
        cols["t_sim"].append(float(now))

        router = self._router
        if router is not None:
            for k, b in enumerate(router.pools.thresholds):
                cols[f"threshold.{k}"].append(int(b))
            spills = router.spill_count
        else:
            spills = 0
        cols["spills"].append(spills - self._prev_spills)
        self._c_spills.add(spills - self._prev_spills)
        self._prev_spills = spills

        for j, (name, pool) in enumerate(zip(self.pool_names, self._pools)):
            st = pool.state
            slots = st.num_instances * st.config.n_seq
            kv = pool.kv_occupancy()
            cols[f"queue_depth.{name}"].append(int(st.queue_depth))
            cols[f"active.{name}"].append(int(st.active))
            cols[f"slot_frac.{name}"].append(st.active / max(1, slots))
            cols[f"kv_frac.{name}"].append(kv)
            self._g_queue[j].set(st.queue_depth)
            self._g_active[j].set(st.active)
            self._g_kv[j].set(kv)
            for col, prev, cur, ctr in (
                ("preemptions", self._prev_pre, pool.preemptions, self._c_pre),
                ("rejections", self._prev_rej, pool.rejections, self._c_rej),
                ("truncations", self._prev_trunc, pool.truncations, self._c_trunc),
            ):
                delta = cur - prev[j]
                cols[f"{col}.{name}"].append(delta)
                ctr[j].add(delta)
                prev[j] = cur

        health = self._health
        if health is not None:
            cols["retries"].append(health.retries - self._prev_retries)
            self._prev_retries = health.retries
            cols["timeouts"].append(health.timeouts - self._prev_timeouts)
            self._prev_timeouts = health.timeouts
            for j, name in enumerate(self.pool_names):
                cols[f"down.{name}"].append(int(health.down_count[j]))
                cols[f"failures.{name}"].append(
                    health.failures[j] - self._prev_fail[j]
                )
                self._prev_fail[j] = health.failures[j]
                cols[f"breaker_open.{name}"].append(
                    int(health.is_open(j, now))
                )

        if router is not None:
            self._sample_calibration(cols, now, lo, hi)

    def _sample_calibration(self, cols: dict, now: float, lo: int, hi: int) -> None:
        """Per-category ``|est − true| / true`` over the window slice, using
        the calibration state as read at the window boundary, plus the live
        EMA ratios; emits a ``calib_sync`` event when observations landed."""
        calib = self._router.calibrator
        have_trace = self._byte_len is not None and hi > lo
        if have_trace:
            hi = min(hi, len(self._byte_len))
            byte = self._byte_len[lo:hi].astype(np.float64)
            cat = self._category[lo:hi]
            true = self._true_input[lo:hi].astype(np.float64)
        for k in range(self._num_categories):
            ratio = calib.conservative_ratio(k)
            cols[f"ema_ratio.cat{k}"].append(float(calib.ratio[k]))
            err = math.nan
            if have_trace:
                m = cat == k
                if m.any():
                    est = np.ceil(byte[m] / ratio)
                    err = float(
                        np.mean(np.abs(est - true[m]) / np.maximum(true[m], 1.0))
                    )
            cols[f"calib_err.cat{k}"].append(err)
        if have_trace and self._mot is not None:
            ratios = np.array(
                [calib.conservative_ratio(k) for k in range(self._num_categories)]
            )
            est_total = np.ceil(byte / ratios[cat]) + self._mot[lo:hi]
            self.budget_hist.observe_many(est_total)
        total_obs = sum(calib.count)
        if self.events is not None and total_obs != self._prev_calib:
            self.events.emit(
                CALIB_SYNC, now, ROUTER_TRACK, -1, total_obs - self._prev_calib
            )
        self._prev_calib = total_obs

    # -- views / exports -------------------------------------------------------
    @property
    def num_samples(self) -> int:
        return len(self.columns["t_req"])

    def column(self, name: str) -> np.ndarray:
        return np.asarray(self.columns[name], dtype=np.float64)

    def to_dict(self) -> dict:
        version = 1 if self._health is None else 2
        return {
            "schema": f"repro.obs/telemetry-v{version}",
            "window": self.config.window,
            "pools": list(self.pool_names),
            "num_samples": self.num_samples,
            "columns": {
                name: [None if isinstance(v, float) and math.isnan(v) else v for v in vals]
                for name, vals in self.columns.items()
            },
            "registry": self.registry.snapshot(),
        }

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    def to_csv(self) -> str:
        """Flat wide CSV: one row per window, dotted column names."""
        names = list(self.columns)
        buf = io.StringIO()
        buf.write(",".join(names) + "\n")
        for row in zip(*(self.columns[n] for n in names)):
            buf.write(
                ",".join(
                    ""
                    if isinstance(v, float) and math.isnan(v)
                    else f"{v:.6g}"
                    if isinstance(v, float)
                    else str(v)
                    for v in row
                )
                + "\n"
            )
        return buf.getvalue()
