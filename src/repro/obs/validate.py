"""Schema validators for the telemetry exports.

Each function parses an exported artifact, raises ``ValueError`` with a
pointed message on the first violation, and returns the parsed object on
success — so the CI telemetry smoke (``benchmarks/telemetry_smoke.py``) and
the unit tests share one definition of "well-formed".
"""

from __future__ import annotations

import json

from repro.obs.events import EVENT_NAMES

TELEMETRY_SCHEMA = "repro.obs/telemetry-v1"
#: v2 = v1 plus the fault/health columns (fleet ``retries``/``timeouts``
#: and per-pool ``down``/``failures``/``breaker_open``); emitted whenever
#: the fleet ran with a :class:`~repro.sim.faults.FaultInjector` attached.
TELEMETRY_SCHEMA_V2 = "repro.obs/telemetry-v2"
TELEMETRY_SCHEMAS = (TELEMETRY_SCHEMA, TELEMETRY_SCHEMA_V2)
EVENTS_SCHEMA = "repro.obs/events-v1"

#: Fleet-level columns every telemetry export carries.
REQUIRED_COLUMNS = ("t_req", "t_sim", "spills")
#: Per-pool column families (``<family>.<pool>``).
POOL_COLUMNS = (
    "queue_depth",
    "active",
    "slot_frac",
    "kv_frac",
    "preemptions",
    "rejections",
    "truncations",
)
#: Extra fleet-level columns required by telemetry-v2.
REQUIRED_COLUMNS_V2 = ("retries", "timeouts")
#: Extra per-pool column families required by telemetry-v2.
POOL_COLUMNS_V2 = ("down", "failures", "breaker_open")


def validate_telemetry(doc) -> dict:
    """Validate a ``FleetTelemetry.to_dict()`` / ``to_json()`` artifact."""
    if isinstance(doc, (str, bytes)):
        doc = json.loads(doc)
    if doc.get("schema") not in TELEMETRY_SCHEMAS:
        raise ValueError(f"bad telemetry schema id: {doc.get('schema')!r}")
    v2 = doc["schema"] == TELEMETRY_SCHEMA_V2
    pools = doc.get("pools")
    if not isinstance(pools, list) or not pools:
        raise ValueError(f"telemetry 'pools' must be a non-empty list: {pools!r}")
    cols = doc.get("columns")
    if not isinstance(cols, dict):
        raise ValueError("telemetry 'columns' must be a dict of lists")
    n = doc.get("num_samples")
    required = REQUIRED_COLUMNS + (REQUIRED_COLUMNS_V2 if v2 else ())
    pool_fams = POOL_COLUMNS + (POOL_COLUMNS_V2 if v2 else ())
    for name in required:
        if name not in cols:
            raise ValueError(f"missing telemetry column {name!r}")
    for pool in pools:
        for fam in pool_fams:
            if f"{fam}.{pool}" not in cols:
                raise ValueError(f"missing per-pool column {fam}.{pool!r}")
    for name, vals in cols.items():
        if not isinstance(vals, list) or len(vals) != n:
            raise ValueError(
                f"column {name!r} has {len(vals) if isinstance(vals, list) else '?'}"
                f" samples, expected num_samples={n}"
            )
    if not all(
        b >= a for a, b in zip(cols["t_req"], cols["t_req"][1:])
    ):
        raise ValueError("t_req must be non-decreasing")
    return doc


def validate_events_jsonl(text: str) -> list[dict]:
    """Validate an ``EventTrace.to_jsonl()`` export; returns the events."""
    lines = [ln for ln in text.splitlines() if ln.strip()]
    if not lines:
        raise ValueError("empty JSONL export")
    header = json.loads(lines[0])
    if header.get("schema") != EVENTS_SCHEMA:
        raise ValueError(f"bad events schema id: {header.get('schema')!r}")
    tracks = set(header.get("pools", ())) | {"router"}
    events = []
    for i, ln in enumerate(lines[1:], start=2):
        e = json.loads(ln)
        for field in ("kind", "t", "pool", "request_id", "value"):
            if field not in e:
                raise ValueError(f"line {i}: missing field {field!r}")
        if e["kind"] not in EVENT_NAMES:
            raise ValueError(f"line {i}: unknown event kind {e['kind']!r}")
        if e["pool"] not in tracks:
            raise ValueError(f"line {i}: unknown pool {e['pool']!r}")
        if e["t"] < 0:
            raise ValueError(f"line {i}: negative timestamp {e['t']}")
        events.append(e)
    return events


def validate_chrome_trace(text: str) -> dict:
    """Validate an ``EventTrace.to_chrome_trace()`` export.

    Checks the trace-event envelope Perfetto requires: a ``traceEvents``
    list, ``thread_name`` metadata for every referenced track, and
    well-formed instant events (``ph: "i"`` with µs ``ts``).
    """
    doc = json.loads(text)
    evs = doc.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        raise ValueError("traceEvents must be a non-empty list")
    named_tids = set()
    for e in evs:
        ph = e.get("ph")
        if ph == "M":
            if e.get("name") == "thread_name":
                named_tids.add(e.get("tid"))
            continue
        if ph != "i":
            raise ValueError(f"unexpected phase {ph!r} (only M/i are emitted)")
        if e.get("name") not in EVENT_NAMES:
            raise ValueError(f"unknown instant name {e.get('name')!r}")
        if not isinstance(e.get("ts"), (int, float)) or e["ts"] < 0:
            raise ValueError(f"bad ts on instant: {e.get('ts')!r}")
        if e.get("pid") != 0 or e.get("tid") not in named_tids:
            raise ValueError(
                f"instant on unnamed track pid={e.get('pid')} tid={e.get('tid')}"
            )
    return doc
