"""Fleet observability: windowed time series, metrics, and event tracing.

The paper's claims are about *observable* fleet dynamics — the error
composite driving §8 adaptive control, the KV-pressure incidents behind
§4.3 reliability, the α/ρ occupancies of Eq. 7. This package turns both DES
backends into sources of those observables:

* :class:`~repro.obs.registry.MetricsRegistry` — O(1), allocation-free
  counters / gauges / fixed-bucket histograms over one preallocated slab;
* :class:`~repro.obs.timeseries.FleetTelemetry` — per-window time series
  sampled on control-window boundaries, surfaced as
  ``FleetResult.telemetry`` with ``to_json()`` / ``to_csv()``;
* :class:`~repro.obs.events.EventTrace` — a bounded ring buffer of typed
  events exportable as JSONL and Chrome trace-event JSON (Perfetto-loadable,
  one pool per track);
* :mod:`~repro.obs.validate` — schema validators shared by CI and tests.

Enable via ``FleetSim(..., telemetry=TelemetryConfig(events=True))`` (or
``telemetry=True`` for defaults). With telemetry off (the default) the
simulation takes zero extra work: every emission site is behind a
``tracer is not None`` guard and no registry exists.

Window semantics
----------------
Windows are counted in **dispatched requests**, not sim time: a sample
covers dispatch positions ``[lo, hi)`` of the arrival-ordered trace and is
taken the moment request ``hi`` has been dispatched. When an
``AdaptiveController`` is installed the sampling window *is* the control
window — each row captures exactly the per-pool deltas the controller acted
on, immediately **after** its boundary move (so ``threshold.*`` shows the
post-move vector, matching what the next window's requests will see). The
vectorized backend may overshoot a boundary by at most one coalesced
round, which is why routed-fleet series are tolerance-matched rather than
bit-equal across backends (see ``tests/test_vector_engine.py``). One final
telemetry-only sample (no controller step) is appended after the drain so
the series always covers the full run.

Telemetry JSON schema — ``repro.obs/telemetry-v1`` / ``-v2``
------------------------------------------------------------
``FleetTelemetry.to_json()`` emits one object (schema id is ``-v2`` when
the fleet ran with a :class:`~repro.sim.faults.FaultInjector` attached,
``-v1`` otherwise; v2 is a strict superset of v1)::

    schema       "repro.obs/telemetry-v1" | "repro.obs/telemetry-v2"
    window       sampling window in dispatched requests (null → control window)
    pools        pool names in budget order (threshold / controller frame)
    num_samples  number of rows; every column has exactly this length
    columns      flat dict of per-window series, dotted names:
      t_req              int   dispatched requests at the window boundary
      t_sim              float sim time (s) of the sample
      spills             int   router spillovers in the window (delta)
      threshold.<k>      int   boundary B_k AFTER any controller move
      queue_depth.<pool> int   live queued requests at the boundary
      active.<pool>      int   live occupied decode slots
      slot_frac.<pool>   float active / (num_instances * n_seq)
      kv_frac.<pool>     float 1 − blocks_free / total_blocks, pool-wide
      preemptions.<pool> int   preemptions in the window (delta)
      rejections.<pool>  int   rejections in the window (delta)
      truncations.<pool> int   truncations in the window (delta)
      calib_err.cat<k>   float mean |est−true|/max(true,1) over the window's
                               dispatches of category k (null if none),
                               with est = ceil(bytes/ĉ_k^route) at the boundary
      ema_ratio.cat<k>   float live EMA bytes/token ratio ĉ_k
      -- telemetry-v2 only (fault injection attached) --
      retries            int   retry resubmissions in the window (delta)
      timeouts           int   deadline-exceeded drops in the window (delta)
      down.<pool>        int   instances currently down (gauge at boundary)
      failures.<pool>    int   in-flight requests lost in the window (delta)
      breaker_open.<pool> int  1 if the pool's circuit breaker is open at
                               the boundary, else 0
    registry     MetricsRegistry.snapshot(): final gauge/counter values and
                 the estimated-budget histogram (edges in tokens)

``to_csv()`` flattens the same columns, one row per window (NaN → empty).

Event schema — ``repro.obs/events-v1``
--------------------------------------
``EventTrace.to_jsonl()``: first line is a header (schema id, pool names,
emitted/dropped counts), then one object per event::

    kind        arrival | dispatch | admit | preempt | truncate | reject |
                spill | threshold_move | calib_sync | fail | recover |
                retry | timeout | shed
    t           sim time (s)
    pool        pool name, or "router" for fleet-level events
    request_id  subject request (-1 for fleet-level events)
    value       kind-specific payload: estimated L_total (dispatch),
                new B_k (threshold_move, with request_id = boundary index),
                EMA observations folded (calib_sync), lost in-flight count
                (crash/OOM ``fail``, request_id = instance index) or slow
                factor (slowdown ``fail``), retry attempt number (``retry``,
                pool = the re-route target), else 0. ``timeout`` and
                ``shed`` are router-track terminal drops (retry budget or
                deadline exhausted).

``to_chrome_trace()`` renders the same events as Chrome trace-event JSON —
instant events (``ph: "i"``, ``ts`` in µs) on one named thread per pool
plus a ``router`` thread — loadable directly in Perfetto.
"""

from repro.obs.events import (
    ADMIT,
    ARRIVAL,
    CALIB_SYNC,
    DISPATCH,
    EVENT_NAMES,
    FAIL,
    PREEMPT,
    RECOVER,
    REJECT,
    RETRY,
    ROUTER_TRACK,
    SHED,
    SPILL,
    THRESHOLD_MOVE,
    TIMEOUT,
    TRUNCATE,
    EventTrace,
)
from repro.obs.registry import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.timeseries import FleetTelemetry, TelemetryConfig
from repro.obs.validate import (
    validate_chrome_trace,
    validate_events_jsonl,
    validate_telemetry,
)

__all__ = [
    "ARRIVAL",
    "DISPATCH",
    "ADMIT",
    "PREEMPT",
    "TRUNCATE",
    "REJECT",
    "SPILL",
    "THRESHOLD_MOVE",
    "CALIB_SYNC",
    "FAIL",
    "RECOVER",
    "RETRY",
    "TIMEOUT",
    "SHED",
    "EVENT_NAMES",
    "ROUTER_TRACK",
    "EventTrace",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "FleetTelemetry",
    "TelemetryConfig",
    "validate_telemetry",
    "validate_events_jsonl",
    "validate_chrome_trace",
]
