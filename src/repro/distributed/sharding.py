"""Logical-axis sharding: model code names axes, meshes map them.

Models annotate every parameter / activation dimension with a *logical* axis
name ("vocab", "heads", "ffn", "experts", "batch", ...). A :class:`AxisRules`
table maps logical names to mesh axes, so the same model definition runs on
the single-pod ``("data","model")`` mesh, the multi-pod
``("pod","data","model")`` mesh, or a laptop 1-device mesh without edits —
the MaxText/Flax "logical axis rules" pattern, implemented standalone.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[str, tuple[str, ...], None]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Mapping from logical axis names to mesh axis names."""

    rules: tuple[tuple[str, MeshAxes], ...]

    def lookup(self, logical: Optional[str], mesh: Mesh) -> MeshAxes:
        if logical is None:
            return None
        for name, target in self.rules:
            if name == logical:
                return _filter_present(target, mesh)
        return None

    def spec(self, logical_axes: Sequence[Optional[str]], mesh: Mesh) -> P:
        """PartitionSpec for a tensor annotated with logical axis names.

        Mesh axes may appear at most once in a PartitionSpec; later duplicate
        uses degrade to replication on that dimension (with the first use
        winning), which matches the conservative GSPMD default.
        """
        used: set[str] = set()
        parts: list[MeshAxes] = []
        for logical in logical_axes:
            target = self.lookup(logical, mesh)
            target_t = (
                (target,) if isinstance(target, str) else tuple(target or ())
            )
            fresh = tuple(a for a in target_t if a not in used)
            used.update(fresh)
            if not fresh:
                parts.append(None)
            elif len(fresh) == 1:
                parts.append(fresh[0])
            else:
                parts.append(fresh)
        return P(*parts)


def _filter_present(target: MeshAxes, mesh: Mesh) -> MeshAxes:
    """Drop mesh axes the current mesh doesn't have (e.g. no "pod" axis)."""
    if target is None:
        return None
    names = set(mesh.axis_names)
    if isinstance(target, str):
        return target if target in names else None
    kept = tuple(a for a in target if a in names)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


#: Default rules for the production meshes (DESIGN.md §6).
DEFAULT_RULES = AxisRules(
    rules=(
        # data-like
        ("batch", ("pod", "data")),
        ("serve_batch", ("pod", "data")),
        # model/tensor parallel
        ("vocab", "model"),
        ("heads", "model"),
        ("kv_heads", "model"),
        ("ffn", "model"),
        ("experts", "model"),
        ("ssm_heads", "model"),
        ("kv_seq", "model"),  # MQA decode: shard cache sequence instead
        # sequence parallelism over the data axis (long-context, batch=1)
        ("seq_data", "data"),
        # never sharded
        ("layers", None),
        ("embed", None),
        ("seq", None),
        ("head_dim", None),
        ("state", None),
        ("conv", None),
        ("codebooks", None),
    )
)


def make_sharding(
    mesh: Mesh, rules: AxisRules, logical_axes: Sequence[Optional[str]]
) -> NamedSharding:
    return NamedSharding(mesh, rules.spec(logical_axes, mesh))


def tree_pspecs(axes_tree: Any, mesh: Mesh, rules: AxisRules = DEFAULT_RULES):
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda axes: rules.spec(axes, mesh),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(a, (str, type(None))) for a in x),
    )


def tree_shardings(axes_tree: Any, mesh: Mesh, rules: AxisRules = DEFAULT_RULES):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        tree_pspecs(axes_tree, mesh, rules),
        is_leaf=lambda x: isinstance(x, P),
    )


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]], rules: AxisRules = DEFAULT_RULES) -> jax.Array:
    """In-graph sharding hint; no-op outside a mesh context."""
    try:
        mesh = _current_mesh()
        if mesh is None or mesh.empty:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, rules.spec(logical_axes, mesh))
        )
    except Exception:
        return x


def _current_mesh() -> Optional[Mesh]:
    try:
        from jax._src.mesh import thread_resources

        mesh = thread_resources.env.physical_mesh
        return mesh
    except Exception:
        return None
