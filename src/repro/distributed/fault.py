"""Fault tolerance: failure detection, elastic re-meshing, straggler policy.

Production story (1000+ nodes):

* every host heartbeats; the coordinator marks hosts dead after
  ``timeout_s`` (here: :class:`HealthMonitor`, driven by tests/examples);
* on failure the launcher rebuilds the largest valid mesh from surviving
  devices (:func:`elastic_mesh`), restores the latest checkpoint with the
  *new* shardings (resharding happens in ``device_put`` — the checkpoint
  format is layout-free), and resumes from the step counter (the data
  pipeline is seekable, so no data is lost or repeated);
* stragglers: serving-side, pool spillover absorbs slow instances
  (Algorithm 1); training-side, :class:`StepTimer` flags outlier steps so
  the launcher can evict persistent stragglers at the next elastic restart
  (synchronous SGD keeps steps bit-reproducible — we trade tail latency for
  determinism, and mitigate with eviction rather than async updates).
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Optional, Sequence

import jax
from jax.sharding import Mesh


@dataclasses.dataclass
class HealthMonitor:
    """Heartbeat bookkeeping for the launcher's retry loop.

    ``clock`` supplies "now" whenever a call omits an explicit timestamp —
    it defaults to wall time (:func:`time.monotonic`) but is injectable so
    the fleet simulator can drive the monitor on *sim* time and replay a
    run deterministically.

    ``mark_dead`` is authoritative even for hosts that never heartbeated:
    the host becomes *known* (so ``alive_hosts``/``dead_hosts`` partition
    the same host set) and stays excluded until :meth:`revive`.
    """

    timeout_s: float = 60.0
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self) -> None:
        self.last_seen: dict[int, float] = {}
        self.dead: set[int] = set()

    def heartbeat(self, host_id: int, now: Optional[float] = None) -> None:
        self.last_seen[host_id] = self.clock() if now is None else now

    def mark_dead(self, host_id: int) -> None:
        self.dead.add(host_id)
        # A host that never heartbeated must still show up as dead-known,
        # not vanish from both views.
        self.last_seen.setdefault(host_id, -math.inf)

    def revive(self, host_id: int, now: Optional[float] = None) -> None:
        """Clear the dead mark and record a fresh heartbeat."""
        self.dead.discard(host_id)
        self.heartbeat(host_id, now=now)

    def alive_hosts(self, now: Optional[float] = None) -> list[int]:
        t = self.clock() if now is None else now
        return [
            h
            for h, seen in self.last_seen.items()
            if h not in self.dead and t - seen <= self.timeout_s
        ]

    def dead_hosts(self) -> list[int]:
        return sorted(self.dead)


def largest_mesh_shape(
    n_devices: int, *, model_parallel: int, max_data: Optional[int] = None
) -> tuple[int, int]:
    """Largest (data, model) grid from surviving devices.

    Model parallelism is fixed by the model's memory footprint; elasticity
    happens on the data axis (whole TP groups are added/removed).
    """
    if n_devices < model_parallel:
        raise ValueError(
            f"need at least one TP group ({model_parallel}), got {n_devices}"
        )
    data = n_devices // model_parallel
    if max_data is not None:
        data = min(data, max_data)
    return data, model_parallel


def elastic_mesh(
    devices: Optional[Sequence] = None,
    *,
    model_parallel: int = 1,
    axis_names: tuple[str, str] = ("data", "model"),
) -> Mesh:
    """Build the largest (data, model) mesh from the given devices."""
    devs = list(devices if devices is not None else jax.devices())
    data, model = largest_mesh_shape(len(devs), model_parallel=model_parallel)
    import numpy as np

    grid = np.array(devs[: data * model]).reshape(data, model)
    return Mesh(grid, axis_names)


@dataclasses.dataclass
class StepTimer:
    """Detects straggler steps: > multiplier × rolling-median step time."""

    window: int = 32
    multiplier: float = 2.0

    def __post_init__(self) -> None:
        self.history: list[float] = []
        self.straggler_steps: list[int] = []
        self._step = 0

    def record(self, duration_s: float) -> bool:
        """Returns True if this step is a straggler."""
        self._step += 1
        hist = self.history[-self.window :]
        is_straggler = False
        if len(hist) >= 8:
            med = sorted(hist)[len(hist) // 2]
            if duration_s > self.multiplier * med:
                is_straggler = True
                self.straggler_steps.append(self._step)
        self.history.append(duration_s)
        return is_straggler

    @property
    def straggler_rate(self) -> float:
        return len(self.straggler_steps) / max(1, self._step)


class SimulatedFailure(RuntimeError):
    """Raised by tests/examples to exercise the restart path."""
