"""Distributed-optimization collectives.

``compressed_psum`` — int8-quantized all-reduce with error feedback, for
bandwidth-bound gradient synchronization at multi-pod scale: each shard
quantizes its local gradient to int8 with a per-tensor scale, psums the
int8 payload (as int32 accumulators to avoid overflow across ≤2²³ shards),
and dequantizes. The quantization residual is carried in an error-feedback
buffer so the scheme is unbiased over time (Seide et al. 2014; Karimireddy
et al. 2019 EF-SGD).

Used inside ``shard_map`` over the ("pod","data") axes — the explicit
manual-SPMD counterpart of the bf16 all-reduce the GSPMD train step emits.
4× bytes-on-wire reduction vs fp32, 2× vs bf16.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

# jax moved shard_map out of jax.experimental (and renamed check_rep →
# check_vma) across releases; accept both spellings.
if hasattr(jax, "shard_map"):

    def _shard_map(f, *, mesh, in_specs, out_specs):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )

else:  # pragma: no cover - version shim
    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    def _shard_map(f, *, mesh, in_specs, out_specs):
        return _experimental_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(
    x: jax.Array,
    axis_name: Any,
    error: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """int8 all-reduce mean with error feedback (call inside shard_map).

    Returns (mean_gradient fp32, new_error fp32). ``error`` carries the
    local quantization residual from the previous round.
    """
    xf = x.astype(jnp.float32)
    if error is not None:
        xf = xf + error
    q, scale = quantize_int8(xf)
    new_error = xf - dequantize_int8(q, scale)
    # int32 accumulate across shards; scales reduced separately (max-scale
    # renormalization keeps the payload int8-exact on every shard).
    scale_max = jax.lax.pmax(scale, axis_name)
    q_norm = jnp.round(
        q.astype(jnp.float32) * (scale / scale_max)
    ).astype(jnp.int32)
    total = jax.lax.psum(q_norm, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    mean = total.astype(jnp.float32) * scale_max / n
    return mean, new_error


def make_compressed_grad_sync(mesh: Mesh, axis_names: tuple[str, ...] = ("data",)):
    """shard_map-wrapped gradient synchronizer for a pytree of local grads.

    grads are assumed fully replicated along `axis_names` *except* for their
    values (each shard holds its local gradient); returns the int8-mean.
    """
    axes = tuple(a for a in axis_names if a in mesh.axis_names)

    def sync(grads, errors):
        def one(g, e):
            mean = g
            err = e
            for ax in axes:
                mean, err = compressed_psum(mean, ax, err)
            return mean, err

        flat, treedef = jax.tree.flatten(grads)
        eflat = treedef.flatten_up_to(errors)
        out = [one(g, e) for g, e in zip(flat, eflat)]
        return (
            treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]),
        )

    spec = P(*axes)
    return _shard_map(
        sync,
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=(spec, spec),
    )
