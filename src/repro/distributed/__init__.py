"""Distribution: logical-axis sharding, collectives, fault tolerance."""

from repro.distributed.collectives import (
    compressed_psum,
    dequantize_int8,
    make_compressed_grad_sync,
    quantize_int8,
)
from repro.distributed.fault import (
    HealthMonitor,
    SimulatedFailure,
    StepTimer,
    elastic_mesh,
    largest_mesh_shape,
)
from repro.distributed.sharding import (
    DEFAULT_RULES,
    AxisRules,
    constrain,
    make_sharding,
    tree_pspecs,
    tree_shardings,
)

__all__ = [
    "compressed_psum",
    "dequantize_int8",
    "make_compressed_grad_sync",
    "quantize_int8",
    "HealthMonitor",
    "SimulatedFailure",
    "StepTimer",
    "elastic_mesh",
    "largest_mesh_shape",
    "DEFAULT_RULES",
    "AxisRules",
    "constrain",
    "make_sharding",
    "tree_pspecs",
    "tree_shardings",
]
