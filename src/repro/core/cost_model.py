"""Closed-form cost model (paper §1.1 Eq. 1–2, §3 Eq. 6–8, §4.7 Table 5).

Everything here is analytical: plug in a traffic CDF and profiled throughput,
get fleet sizes and dollar savings — no infrastructure change required
(paper contribution 3). The DES in ``repro.sim`` provides the definitive
numbers; this module provides the audit-ahead estimates and the memory-side
capacity math.

Hardware adaptation note (DESIGN.md §3): Eq. 1–2 are hardware-neutral — only
the byte constants change between A100, MI300X and TPU v5e. ``TPU_V5E`` here
is also the single source of truth for the roofline constants used by
``repro.launch.roofline``.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.pools import KV_BLOCK_TOKENS, TOTAL_KV_BLOCKS


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-accelerator capacity + roofline constants."""

    name: str
    hbm_bytes: float
    mem_util: float  # u in Eq. 2 (gpu_memory_utilization)
    cost_per_hour: float  # $/accelerator-hr
    peak_flops_bf16: float  # FLOP/s
    hbm_bw: float  # bytes/s
    ici_bw: float  # bytes/s per link (interconnect)
    accelerators_per_node: int = 8


A100_80G = HardwareSpec(
    name="A100-80GB",
    hbm_bytes=80e9,
    mem_util=0.90,
    cost_per_hour=2.21,  # AWS p4d.24xlarge per-GPU (paper §4.2)
    peak_flops_bf16=312e12,
    hbm_bw=2.039e12,
    ici_bw=600e9 / 2,  # NVLink3 bidirectional/2
    accelerators_per_node=8,
)

MI300X = HardwareSpec(
    name="MI300X",
    hbm_bytes=192e9,
    mem_util=0.90,  # paper §4.7: 10% safety margin
    cost_per_hour=3.67,  # paper Table 5 cloud rate
    peak_flops_bf16=1.3e15,
    hbm_bw=5.3e12,
    ici_bw=128e9,
    accelerators_per_node=8,
)

#: Target platform for this reproduction (roofline constants from the
#: assignment: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).
TPU_V5E = HardwareSpec(
    name="TPU-v5e",
    hbm_bytes=16e9,
    mem_util=0.90,
    cost_per_hour=1.20,  # on-demand us-central ballpark
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    accelerators_per_node=4,  # 2x2 tray
)


@dataclasses.dataclass(frozen=True)
class KVModelSpec:
    """The model-side constants of Eq. 1 (+ weights/activations for Eq. 2)."""

    name: str
    n_layers: int
    n_kv_heads: int
    head_dim: int
    kv_dtype_bytes: int = 2  # BF16 KV (even under FP8 weights — paper §4.7)
    weight_bytes_total: float = 0.0  # all-shard model weights in bytes
    activation_bytes_per_gpu: float = 0.0
    tensor_parallel: int = 1

    # -- Eq. 1 ---------------------------------------------------------------
    def kv_bytes_per_token(self) -> float:
        """2 · n_l · n_h · d_h · b_dtype — whole-model KV bytes per token."""
        return (
            2.0
            * self.n_layers
            * self.n_kv_heads
            * self.head_dim
            * self.kv_dtype_bytes
        )

    def kv_bytes_per_token_per_gpu(self) -> float:
        return self.kv_bytes_per_token() / self.tensor_parallel

    def m_seq(self, c_max: int) -> float:
        """Eq. 1: KV bytes reserved per sequence (whole model)."""
        return self.kv_bytes_per_token() * c_max

    # -- Eq. 2 ---------------------------------------------------------------
    def kv_budget_per_gpu(self, hw: HardwareSpec) -> float:
        """HBM left for KV pages: M_gpu·u − M_model − M_act (per GPU)."""
        weights_per_gpu = self.weight_bytes_total / self.tensor_parallel
        return (
            hw.hbm_bytes * hw.mem_util
            - weights_per_gpu
            - self.activation_bytes_per_gpu
        )

    def n_seq_memory(self, hw: HardwareSpec, c_max: int) -> int:
        """Eq. 2: max concurrent sequences from the memory budget."""
        budget = self.kv_budget_per_gpu(hw)
        per_seq = self.kv_bytes_per_token_per_gpu() * c_max
        if budget <= 0:
            return 0
        return int(budget // per_seq)

    def n_seq_blocks(self, c_max: int, *, max_slots: int = 128) -> int:
        """Appendix-A block-budget slots (matches the paper's Table 1)."""
        blocks_per_seq = math.ceil(c_max / KV_BLOCK_TOKENS)
        return max(0, min(max_slots, TOTAL_KV_BLOCKS // blocks_per_seq))


# Published model specs used by the paper -----------------------------------

LLAMA3_70B_KV = KVModelSpec(
    name="Llama-3-70B",
    n_layers=80,
    n_kv_heads=8,
    head_dim=128,
    kv_dtype_bytes=2,
    weight_bytes_total=140e9,  # 70B BF16
    activation_bytes_per_gpu=4e9,
    tensor_parallel=8,
)

QWEN3_235B_KV = KVModelSpec(
    name="Qwen3-235B-A22B",
    n_layers=94,
    n_kv_heads=4,
    head_dim=128,
    kv_dtype_bytes=2,  # BF16 KV under FP8 weights
    weight_bytes_total=235e9,  # FP8 weights: 1 byte/param
    activation_bytes_per_gpu=10e9,  # paper §4.7
    tensor_parallel=8,
)


# ---------------------------------------------------------------------------
# Fleet economics (Eq. 6–8)
# ---------------------------------------------------------------------------


def closed_form_savings(alpha: float, rho: float) -> float:
    """Eq. 7: savings = α (1 − 1/ρ).

    α: short-traffic fraction F(B_short); ρ: μ(C_S)/μ(C_H) ≥ 1.
    This is the *planning* estimate; it assumes the long pool keeps the
    homogeneous throughput. For heavy tails use :func:`corrected_savings`.
    """
    if not 0.0 <= alpha <= 1.0:
        raise ValueError(f"alpha must be in [0,1], got {alpha}")
    if rho <= 0:
        raise ValueError(f"rho must be positive, got {rho}")
    return alpha * (1.0 - 1.0 / rho)


def homogeneous_fleet(rate: float, mu_homo: float, headroom: float = 1.0) -> int:
    """Eq. 6 first term degenerate case: G_homo = ceil(λ/μ(C_H))·β."""
    return max(1, math.ceil(rate / mu_homo * headroom))


def dual_fleet_naive(
    rate: float, alpha: float, mu_short: float, mu_homo: float
) -> int:
    """Eq. 6 with the *naive* long-pool throughput μ(C_H)."""
    g = 0
    if alpha > 0:
        g += math.ceil(alpha * rate / mu_short)
    if alpha < 1.0:
        g += math.ceil((1.0 - alpha) * rate / mu_homo)
    return max(1, g)


def corrected_savings(
    rate: float,
    alpha: float,
    mu_short: float,
    mu_long_routed: float,
    mu_homo: float,
    *,
    headroom_homo: float = 1.0,
    headroom_short: float = 1.0,
    headroom_long: float = 1.0,
) -> tuple[float, int, int]:
    """Eq. 8 savings. Returns (fraction, G_homo, G_dual).

    μ_long_routed is the long pool's throughput under *routed* (long-only)
    traffic — the quantity whose omission makes Eq. 7 over-predict by up to
    4× on heavy-tailed workloads (paper §4.2, §5).
    """
    g_homo = homogeneous_fleet(rate, mu_homo, headroom_homo)
    g_short = (
        max(1, math.ceil(alpha * rate / mu_short * headroom_short))
        if alpha > 0
        else 0
    )
    g_long = (
        max(1, math.ceil((1.0 - alpha) * rate / mu_long_routed * headroom_long))
        if alpha < 1.0
        else 0
    )
    g_dual = g_short + g_long
    return (g_homo - g_dual) / g_homo, g_homo, g_dual


def annual_cost(instances: int, hw: HardwareSpec, accel_per_instance: int) -> float:
    """$/yr for a fleet of `instances` serving instances."""
    return instances * accel_per_instance * hw.cost_per_hour * 24 * 365


def annual_savings(
    g_homo: int, g_dual: int, hw: HardwareSpec, accel_per_instance: int
) -> float:
    return annual_cost(g_homo - g_dual, hw, accel_per_instance)


# ---------------------------------------------------------------------------
# §4.7 case-study helper
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CaseStudyResult:
    kv_kb_per_token_per_gpu: float
    kv_budget_gb_per_gpu: float
    n_seq_short: int
    n_seq_long: int
    concurrency_ratio: float


def mi300x_case_study(
    spec: KVModelSpec = QWEN3_235B_KV,
    hw: HardwareSpec = MI300X,
    *,
    c_short: int = 8192,
    c_long: int = 32_768,
) -> CaseStudyResult:
    """Reproduce the §4.7 memory math: 23.5 KB/token/GPU, 133.4 GB KV budget,
    676 vs 169 concurrent sequences (4×)."""
    kv_kb = spec.kv_bytes_per_token_per_gpu() / 1024
    budget = spec.kv_budget_per_gpu(hw)
    n_short = spec.n_seq_memory(hw, c_short)
    n_long = spec.n_seq_memory(hw, c_long)
    return CaseStudyResult(
        kv_kb_per_token_per_gpu=kv_kb,
        kv_budget_gb_per_gpu=budget / 1e9,
        n_seq_short=n_short,
        n_seq_long=n_long,
        concurrency_ratio=n_short / max(1, n_long),
    )
