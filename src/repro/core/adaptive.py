"""Error-driven threshold discovery (paper §7 Future Work — implemented).

The paper proposes turning B_short into a self-tuning control variable
driven by the engines' own failure/pressure signals. This controller uses
AIMD (additive-increase / multiplicative-decrease), the classic stable
feedback law:

* **error pressure** (short-pool preemptions, truncations, rejections, or
  hard queue overload) → multiplicative *decrease*: mis-routed heavy
  requests are being forced into the small pool, shift the boundary down;
* **quiet windows with long-pool slack** → additive *increase*: capture
  more traffic in the cheap pool (the savings gradient in Fig. 6 is
  monotone for heavy-tailed traffic).

The controller never crosses the hard bound B_short ≤ C_max(P_s), and its
moves are clamped so one bad window cannot flap the fleet.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class AdaptiveThreshold:
    b_short: int
    b_min: int = 1024
    b_max: int = 8192  # short pool C_max
    increase_step: int = 512
    decrease_factor: float = 0.75
    error_rate_hi: float = 0.01  # §8: alert when 5-min preemption rate >1%
    overload_ratio_hi: float = 2.0  # short queue ≥ 2× long queue slack

    def __post_init__(self) -> None:
        self.b_short = min(max(self.b_short, self.b_min), self.b_max)
        self.history: list[tuple[int, str]] = []

    def update(
        self,
        *,
        window_requests: int,
        short_errors: int,
        short_queue: int,
        short_instances: int,
        long_queue: int,
        long_instances: int,
    ) -> int:
        """One control step per monitoring window. Returns the new B_short.

        Pressure = queued requests per instance (the same quantity the
        spillover clause reads); errors = preemptions+rejections+truncations
        in the window.
        """
        if window_requests <= 0:
            return self.b_short
        err_rate = short_errors / window_requests
        short_pressure = short_queue / max(1, short_instances)
        long_pressure = long_queue / max(1, long_instances)

        if err_rate > self.error_rate_hi or (
            short_pressure > self.overload_ratio_hi * max(long_pressure, 0.25)
            and short_pressure > 1.0
        ):
            new_b = int(self.b_short * self.decrease_factor)
            reason = "decrease"
        elif long_pressure < 0.25 and short_pressure < 1.0:
            new_b = self.b_short + self.increase_step
            reason = "increase"
        else:
            new_b = self.b_short
            reason = "hold"
        new_b = min(max(new_b, self.b_min), self.b_max)
        if new_b != self.b_short:
            self.history.append((new_b, reason))
        self.b_short = new_b
        return new_b
