"""Error-driven threshold discovery (paper §7 Future Work — implemented).

The paper proposes turning the routing boundaries into self-tuning control
variables driven by the engines' own failure/pressure signals. Both
controllers here apply AIMD (additive-increase / multiplicative-decrease),
the classic stable feedback law, per boundary ``B_k`` between pool ``k``
and pool ``k+1``:

* **error pressure** (pool-k preemptions, truncations, rejections, or hard
  queue overload) → multiplicative *decrease*: mis-routed heavy requests
  are being forced into a too-small pool, shift the boundary down;
* **quiet windows with upstream slack** (pool ``k+1`` near-idle and pool
  ``k`` unpressured) → additive *increase*: capture more traffic in the
  cheaper pool (the savings gradient in Fig. 6 is monotone for heavy-tailed
  traffic).

A boundary never crosses the hard bound ``B_k ≤ C_max,k`` and the strict
ordering ``B_1 < … < B_{P-1}`` is preserved on every step, so one bad
window cannot flap the fleet or wedge the router.

:class:`AdaptiveController` is the first-class N-boundary form operating on
any :class:`~repro.core.pools.PoolSet` — plug it into the fleet simulator
via ``FleetSim(controller=..., control_window=...)`` and both backends will
feed it windowed per-pool error/queue deltas. :class:`AdaptiveThreshold` is
the original two-pool scalar form, kept as a compatibility layer for code
that manages ``b_short`` by hand.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.pools import PoolSet

#: AIMD defaults shared by both controller forms (§8: alert when the 5-min
#: preemption rate exceeds 1%; pressure in queued-requests-per-instance).
DEFAULT_INCREASE_STEP = 512
DEFAULT_DECREASE_FACTOR = 0.75
DEFAULT_ERROR_RATE_HI = 0.01
DEFAULT_OVERLOAD_RATIO_HI = 2.0
#: Pressure floors: below ``_PRESSURE_IDLE`` a pool counts as slack, above
#: ``_PRESSURE_BUSY`` it is materially loaded.
_PRESSURE_IDLE = 0.25
_PRESSURE_BUSY = 1.0


def _aimd_move(
    *,
    err_rate: float,
    pressure_lo: float,
    pressure_hi: float,
    error_rate_hi: float,
    overload_ratio_hi: float,
) -> str:
    """One AIMD decision for a boundary between a low (cheap) pool and its
    high-capacity neighbour. Returns ``"decrease" | "increase" | "hold"``.

    ``errors = preemptions + rejections + truncations`` in the window —
    every way the low pool can fail a request it should not have been sent.
    """
    if err_rate > error_rate_hi or (
        pressure_lo > overload_ratio_hi * max(pressure_hi, _PRESSURE_IDLE)
        and pressure_lo > _PRESSURE_BUSY
    ):
        return "decrease"
    if pressure_hi < _PRESSURE_IDLE and pressure_lo < _PRESSURE_BUSY:
        return "increase"
    return "hold"


@dataclasses.dataclass(frozen=True)
class BoundaryMove:
    """One recorded controller action (the trajectory unit).

    Besides the move itself, the record carries the windowed signals that
    caused it — the same per-pool observables the telemetry layer samples —
    so a trajectory is self-explaining without replaying the run.
    """

    t: int  # requests dispatched when the move fired
    boundary: int  # k: index into the threshold vector
    value: int  # B_k after the move
    reason: str  # "decrease" | "increase" | "clamp"
    #: Windowed error rate of the low pool (errors / window_requests).
    err_rate: float = 0.0
    #: Queue pressure (queued per instance) of the pool below the boundary.
    pressure_lo: float = 0.0
    #: Queue pressure of the pool above the boundary.
    pressure_hi: float = 0.0


class AdaptiveController:
    """N-boundary AIMD threshold control over a budget-ordered PoolSet.

    Each monitoring window the fleet reports, per pool (budget order):
    windowed error counts (preemptions + rejections + truncations), live
    queue depths, and instance counts. Every boundary ``B_k`` then takes
    one AIMD step from the pressure of the pool pair it separates, and the
    whole threshold vector is applied atomically through
    :meth:`~repro.core.pools.PoolSet.set_thresholds` — clamped to
    ``[b_min, C_max,k]`` and kept strictly increasing, so the PoolSet (and
    the router's aliased hot-path view) never sees an invalid ordering.
    """

    def __init__(
        self,
        pool_set: Optional[PoolSet] = None,
        *,
        b_min: int = 512,
        increase_step: int = DEFAULT_INCREASE_STEP,
        decrease_factor: float = DEFAULT_DECREASE_FACTOR,
        error_rate_hi: float = DEFAULT_ERROR_RATE_HI,
        overload_ratio_hi: float = DEFAULT_OVERLOAD_RATIO_HI,
    ) -> None:
        if not 0.0 < decrease_factor < 1.0:
            raise ValueError(f"decrease_factor must be in (0,1): {decrease_factor}")
        self.b_min = int(b_min)
        self.increase_step = int(increase_step)
        self.decrease_factor = float(decrease_factor)
        self.error_rate_hi = float(error_rate_hi)
        self.overload_ratio_hi = float(overload_ratio_hi)
        self.pool_set: Optional[PoolSet] = None
        self.history: list[BoundaryMove] = []
        if pool_set is not None:
            self.bind(pool_set)

    def bind(self, pool_set: PoolSet) -> None:
        """Attach to the PoolSet whose thresholds this controller moves."""
        if len(pool_set) < 2:
            raise ValueError("adaptive control needs at least two pools")
        self.pool_set = pool_set

    @property
    def thresholds(self) -> list[int]:
        """Current boundary vector (live view of the bound PoolSet)."""
        if self.pool_set is None:
            raise RuntimeError("controller is not bound to a PoolSet")
        return [int(b) for b in self.pool_set.thresholds]

    def update(
        self,
        *,
        window_requests: int,
        errors: Sequence[int],
        queues: Sequence[int],
        instances: Sequence[int],
        t: int = 0,
    ) -> list[int]:
        """One control step per monitoring window; returns the new vector.

        ``errors``/``queues``/``instances`` are per-pool in budget order
        (length P). ``errors[k]`` is the *windowed* delta of
        preemptions + rejections + truncations in pool ``k``; queues and
        instances are read live at the window boundary.
        """
        pools = self.pool_set
        if pools is None:
            raise RuntimeError("controller is not bound to a PoolSet")
        p = len(pools)
        if not (len(errors) == len(queues) == len(instances) == p):
            raise ValueError(
                f"need per-pool signals of length {p}: got "
                f"{len(errors)}/{len(queues)}/{len(instances)}"
            )
        old = [int(b) for b in pools.thresholds]
        if window_requests <= 0:
            return old

        pressure = [
            queues[k] / max(1, instances[k]) for k in range(p)
        ]
        proposal = list(old)
        reasons = ["hold"] * (p - 1)
        for k in range(p - 1):
            move = _aimd_move(
                err_rate=errors[k] / window_requests,
                pressure_lo=pressure[k],
                pressure_hi=pressure[k + 1],
                error_rate_hi=self.error_rate_hi,
                overload_ratio_hi=self.overload_ratio_hi,
            )
            if move == "decrease":
                proposal[k] = int(old[k] * self.decrease_factor)
            elif move == "increase":
                proposal[k] = old[k] + self.increase_step
            reasons[k] = move

        new = self._clamp(proposal, old)
        if new != old:
            pools.set_thresholds(new)
            for k in range(p - 1):
                if new[k] != old[k]:
                    reason = reasons[k] if reasons[k] != "hold" else "clamp"
                    self.history.append(
                        BoundaryMove(
                            t=t,
                            boundary=k,
                            value=new[k],
                            reason=reason,
                            err_rate=errors[k] / window_requests,
                            pressure_lo=pressure[k],
                            pressure_hi=pressure[k + 1],
                        )
                    )
        return new

    def _clamp(self, proposal: list[int], old: list[int]) -> list[int]:
        """Feasibility projection: ``b_min ≤ B_k ≤ C_max,k`` with strict
        ordering, by a single forward pass with a running lower bound —
        valid by construction. Falls back to ``old`` (the last valid
        vector) in the degenerate case where no strictly increasing vector
        fits under the capacity caps."""
        pools = self.pool_set
        assert pools is not None
        lo = self.b_min
        new: list[int] = []
        for k, b in enumerate(proposal):
            cap = pools.configs[k].c_max  # B_k ≤ C_max,k (hard bound)
            if lo > cap:
                return list(old)
            new.append(min(max(b, lo), cap))
            lo = new[k] + 1
        return new


@dataclasses.dataclass
class AdaptiveThreshold:
    """Two-pool scalar AIMD controller (compatibility form).

    Owns its ``b_short`` copy rather than a PoolSet; callers are expected
    to push the returned boundary into their router by hand. New code
    should use :class:`AdaptiveController` with the ``FleetSim``
    ``controller=`` hook instead.
    """

    b_short: int
    b_min: int = 1024
    b_max: int = 8192  # short pool C_max
    increase_step: int = DEFAULT_INCREASE_STEP
    decrease_factor: float = DEFAULT_DECREASE_FACTOR
    error_rate_hi: float = DEFAULT_ERROR_RATE_HI
    overload_ratio_hi: float = DEFAULT_OVERLOAD_RATIO_HI

    def __post_init__(self) -> None:
        self.b_short = min(max(self.b_short, self.b_min), self.b_max)
        self.history: list[tuple[int, str]] = []

    def update(
        self,
        *,
        window_requests: int,
        short_errors: int,
        short_queue: int,
        short_instances: int,
        long_queue: int,
        long_instances: int,
    ) -> int:
        """One control step per monitoring window. Returns the new B_short.

        Pressure = queued requests per instance (the same quantity the
        spillover clause reads); errors = preemptions+rejections+truncations
        in the window.
        """
        if window_requests <= 0:
            return self.b_short
        move = _aimd_move(
            err_rate=short_errors / window_requests,
            pressure_lo=short_queue / max(1, short_instances),
            pressure_hi=long_queue / max(1, long_instances),
            error_rate_hi=self.error_rate_hi,
            overload_ratio_hi=self.overload_ratio_hi,
        )
        if move == "decrease":
            new_b = int(self.b_short * self.decrease_factor)
        elif move == "increase":
            new_b = self.b_short + self.increase_step
        else:
            new_b = self.b_short
        new_b = min(max(new_b, self.b_min), self.b_max)
        if new_b != self.b_short:
            self.history.append((new_b, move))
        self.b_short = new_b
        return new_b
