"""Traffic categories for per-category bytes-per-token calibration.

The paper (§2.1) tracks one EMA ratio per *traffic category* k — e.g. code,
prose, CJK — because tokenizer fertility varies ~3.4x across writing systems.
The category is metadata the routing layer already has (model tag, tenant,
detected script); we model it as a small closed enum plus "mixed/other".

The ``TRUE_BYTES_PER_TOKEN`` values are the ground-truth ratios used by the
synthetic trace generator and by the Table-4 Monte-Carlo calibration study;
they match the paper's reported per-category ratios (§2.1, Table 4).
"""

from __future__ import annotations

import enum


class Category(enum.IntEnum):
    """Traffic category of a request (known at dispatch time)."""

    ENGLISH_PROSE = 0
    SOURCE_CODE = 1
    CJK_TEXT = 2
    MIXED_OTHER = 3


NUM_CATEGORIES = len(Category)

#: Ground-truth bytes-per-token ratios per category (paper Table 4, col. 2).
TRUE_BYTES_PER_TOKEN: dict[Category, float] = {
    Category.ENGLISH_PROSE: 4.48,
    Category.SOURCE_CODE: 3.52,
    Category.CJK_TEXT: 2.01,
    Category.MIXED_OTHER: 3.81,
}

#: Observation noise (std of per-request bytes/token around the category
#: mean) used by the trace generator; chosen so the EMA σ̂ is meaningfully
#: non-zero, as in real traffic.
BYTES_PER_TOKEN_STD: dict[Category, float] = {
    Category.ENGLISH_PROSE: 0.35,
    Category.SOURCE_CODE: 0.40,
    Category.CJK_TEXT: 0.20,
    Category.MIXED_OTHER: 0.55,
}

#: Cold-start prior c0 (paper §2.1): the English-prose average.
COLD_START_RATIO = 4.0

CATEGORY_NAMES = {
    Category.ENGLISH_PROSE: "English prose",
    Category.SOURCE_CODE: "Source code",
    Category.CJK_TEXT: "CJK text",
    Category.MIXED_OTHER: "Mixed / other",
}
