"""Core contribution of the paper: token-budget-aware pool routing.

Public API:

* ``EmaCalibrator`` / ``CalibState`` — self-calibrating bytes-per-token EMA.
* ``TokenBudgetRouter`` / ``Request`` — Algorithm 1 dispatch (N-pool).
* ``AdaptiveController`` — N-boundary AIMD threshold control (§7/§8).
* ``PoolConfig`` / ``PoolSet`` / ``short_pool`` / ``long_pool`` — pool
  definitions and the budget-ordered pool family.
* ``closed_form_savings`` / ``corrected_savings`` — Eq. 7 / Eq. 8.
"""

from repro.core.adaptive import (
    AdaptiveController,
    AdaptiveThreshold,
    BoundaryMove,
)
from repro.core.calibration import (
    CalibState,
    EmaCalibrator,
    init_state,
    jax_estimate_budget,
    jax_update,
    jax_update_stream,
)
from repro.core.categories import (
    CATEGORY_NAMES,
    COLD_START_RATIO,
    NUM_CATEGORIES,
    TRUE_BYTES_PER_TOKEN,
    Category,
)
from repro.core.cost_model import (
    A100_80G,
    LLAMA3_70B_KV,
    MI300X,
    QWEN3_235B_KV,
    TPU_V5E,
    HardwareSpec,
    KVModelSpec,
    annual_cost,
    annual_savings,
    closed_form_savings,
    corrected_savings,
    dual_fleet_naive,
    homogeneous_fleet,
    mi300x_case_study,
)
from repro.core.pools import (
    KV_BLOCK_TOKENS,
    TOTAL_KV_BLOCKS,
    PoolConfig,
    PoolSet,
    PoolState,
    dual_pool_fleet,
    fleet_instances,
    homogeneous_pool,
    long_pool,
    n_seq_for_cmax,
    short_pool,
)
from repro.core.router import (
    LONG,
    SHORT,
    Request,
    RouteDecision,
    TokenBudgetRouter,
    jax_route_batch,
)

__all__ = [
    "AdaptiveController",
    "AdaptiveThreshold",
    "BoundaryMove",
    "CalibState",
    "EmaCalibrator",
    "init_state",
    "jax_estimate_budget",
    "jax_update",
    "jax_update_stream",
    "Category",
    "CATEGORY_NAMES",
    "COLD_START_RATIO",
    "NUM_CATEGORIES",
    "TRUE_BYTES_PER_TOKEN",
    "HardwareSpec",
    "KVModelSpec",
    "A100_80G",
    "MI300X",
    "TPU_V5E",
    "LLAMA3_70B_KV",
    "QWEN3_235B_KV",
    "annual_cost",
    "annual_savings",
    "closed_form_savings",
    "corrected_savings",
    "dual_fleet_naive",
    "homogeneous_fleet",
    "mi300x_case_study",
    "PoolConfig",
    "PoolSet",
    "PoolState",
    "KV_BLOCK_TOKENS",
    "TOTAL_KV_BLOCKS",
    "dual_pool_fleet",
    "fleet_instances",
    "homogeneous_pool",
    "long_pool",
    "n_seq_for_cmax",
    "short_pool",
    "Request",
    "RouteDecision",
    "TokenBudgetRouter",
    "jax_route_batch",
    "SHORT",
    "LONG",
]
