"""Self-calibrating bytes-per-token estimation (paper §2.1, Eq. 4–5).

Two implementations of the same algorithm:

* :class:`EmaCalibrator` — the production host-side path: O(1) scalar updates
  per response, no tokenizer, no JAX dependency on the hot path.
* :func:`jax_update` / :func:`jax_estimate` — a pure-functional JAX version
  operating on a :class:`CalibState` pytree, used for vectorized Monte-Carlo
  studies (Table 4) and for fusing calibration into batched re-routing.

Update rule (Eq. 4), per category k::

    c_obs = |r| / usage.prompt_tokens
    ĉ_k   ← β ĉ_k + (1-β) c_obs
    σ̂_k   ← β σ̂_k + (1-β) |c_obs − ĉ_k|

Conservative routing estimate (Eq. 5)::

    ĉ_k^route = ĉ_k − γ σ̂_k

Routing errors are asymmetric — a long request mis-sent to the short pool
causes preemption, a short request in the long pool only wastes throughput —
so γ>0 biases the token estimate UP (smaller ĉ ⇒ more tokens estimated ⇒
borderline requests go to the safer long pool).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.categories import COLD_START_RATIO, NUM_CATEGORIES

DEFAULT_BETA = 0.95
DEFAULT_GAMMA = 1.0
_MIN_RATIO = 0.25  # bytes/token can't go below 1 byte / 4 tokens in practice

# ---------------------------------------------------------------------------
# Kernel specialization registry
# ---------------------------------------------------------------------------
# The routing / calibration hot path calls the same jitted kernels once per
# routing epoch and once per control window — millions of times across a
# sensitivity grid. Specializations are cached by an explicit key (e.g.
# ``("route", P, dtype)``) via ``functools.lru_cache`` factories so repeated
# calls reuse one compiled object, and each factory bumps a trace counter at
# *trace time* (the Python body of a jitted function runs only while
# tracing), which the test suite uses to prove per-epoch calls stop
# retracing.

_KERNEL_TRACES: dict[tuple, int] = {}


def _count_trace(key: tuple) -> None:
    """Record one tracing of the kernel registered under ``key``."""
    _KERNEL_TRACES[key] = _KERNEL_TRACES.get(key, 0) + 1


def kernel_trace_counts() -> dict[tuple, int]:
    """Snapshot of {kernel key: number of times JAX traced it}."""
    return dict(_KERNEL_TRACES)


@dataclasses.dataclass
class EmaCalibrator:
    """Host-side per-category EMA calibrator (production dispatch path)."""

    num_categories: int = NUM_CATEGORIES
    beta: float = DEFAULT_BETA
    gamma: float = DEFAULT_GAMMA
    c0: float = COLD_START_RATIO

    def __post_init__(self) -> None:
        self.ratio = [self.c0] * self.num_categories
        self.sigma = [0.0] * self.num_categories
        self.count = [0] * self.num_categories

    # -- estimation ---------------------------------------------------------
    def conservative_ratio(self, category: int) -> float:
        """ĉ_k − γ σ̂_k, floored to a sane minimum (Eq. 5)."""
        c = self.ratio[category] - self.gamma * self.sigma[category]
        return max(c, _MIN_RATIO)

    def estimate_input_tokens(self, byte_len: int, category: int) -> int:
        """L_in = ceil(|r| / ĉ_k^route) (Eq. 3, input term)."""
        return math.ceil(byte_len / self.conservative_ratio(category))

    def estimate_total_budget(
        self, byte_len: int, max_output_tokens: int, category: int
    ) -> int:
        """L_total = L_in + L_out (Eq. 3)."""
        return self.estimate_input_tokens(byte_len, category) + max_output_tokens

    # -- feedback -----------------------------------------------------------
    def observe(self, byte_len: int, prompt_tokens: int, category: int) -> float:
        """OnResponse (Algorithm 1 lines 15–19). Returns c_obs.

        The first observation replaces the cold-start prior outright (EMA
        from c0=4.0 would keep ~8% of the initial bias after 50 updates at
        β=0.95 — the paper's ≤3.5% convergence implies first-sample init).
        """
        if prompt_tokens <= 0:
            return self.ratio[category]
        c_obs = byte_len / prompt_tokens
        b = self.beta if self.count[category] > 0 else 0.0
        self.ratio[category] = b * self.ratio[category] + (1.0 - b) * c_obs
        dev = abs(c_obs - self.ratio[category])
        self.sigma[category] = b * self.sigma[category] + (1.0 - b) * dev
        self.count[category] += 1
        return c_obs

    def snapshot(self) -> dict:
        return {
            "ratio": list(self.ratio),
            "sigma": list(self.sigma),
            "count": list(self.count),
        }

    # -- batch feedback (vectorized simulator / trace re-routing) -----------
    def to_state(self) -> "CalibState":
        """Export the scalar EMA state as a JAX :class:`CalibState` pytree."""
        return CalibState(
            ratio=jnp.asarray(self.ratio, dtype=jnp.float32),
            sigma=jnp.asarray(self.sigma, dtype=jnp.float32),
            count=jnp.asarray(self.count, dtype=jnp.int32),
        )

    def load_state(self, state: "CalibState") -> None:
        """Sync the scalar state back from a :class:`CalibState` pytree."""
        self.ratio = [float(x) for x in state.ratio]
        self.sigma = [float(x) for x in state.sigma]
        self.count = [int(x) for x in state.count]

    def observe_batch(
        self,
        byte_lens,
        prompt_tokens,
        categories,
        *,
        chunk: int = 4096,
    ) -> None:
        """Fold a whole observation stream through the EMA (Eq. 4) at once.

        Epoch-batched feedback for the vectorized fleet backend: instead of
        one :meth:`observe` call per response on the hot path, completions
        are accumulated and folded through :func:`jax_update_stream`
        (a jitted ``lax.scan``), then synced back into the scalar state.
        Streams are padded to a fixed ``chunk`` length (padding rows carry
        ``prompt_tokens=0``, which the update kernel skips) so JAX compiles
        the scan exactly once.
        """
        byte_lens = jnp.asarray(byte_lens, dtype=jnp.float32)
        prompt_tokens = jnp.asarray(prompt_tokens, dtype=jnp.float32)
        categories = jnp.asarray(categories, dtype=jnp.int32)
        n = int(byte_lens.shape[0])
        if n == 0:
            return
        kernel = _update_stream_kernel(chunk, float(self.beta))
        state = self.to_state()
        for lo in range(0, n, chunk):
            b = byte_lens[lo : lo + chunk]
            p = prompt_tokens[lo : lo + chunk]
            k = categories[lo : lo + chunk]
            pad = chunk - int(b.shape[0])
            if pad:
                b = jnp.pad(b, (0, pad))
                p = jnp.pad(p, (0, pad))  # prompt_tokens=0 → skipped
                k = jnp.pad(k, (0, pad))
            state = kernel(state, b, p, k)
        self.load_state(state)


# ---------------------------------------------------------------------------
# Pure-functional JAX version (vectorized studies / fused batch routing)
# ---------------------------------------------------------------------------


class CalibState(NamedTuple):
    """Per-category EMA state as a JAX pytree."""

    ratio: jax.Array  # (K,) float32 — ĉ_k
    sigma: jax.Array  # (K,) float32 — σ̂_k
    count: jax.Array  # (K,) int32


def init_state(
    num_categories: int = NUM_CATEGORIES, c0: float = COLD_START_RATIO
) -> CalibState:
    return CalibState(
        ratio=jnp.full((num_categories,), c0, dtype=jnp.float32),
        sigma=jnp.zeros((num_categories,), dtype=jnp.float32),
        count=jnp.zeros((num_categories,), dtype=jnp.int32),
    )


def jax_update(
    state: CalibState,
    byte_len: jax.Array,
    prompt_tokens: jax.Array,
    category: jax.Array,
    *,
    beta: float = DEFAULT_BETA,
) -> CalibState:
    """One EMA update (Eq. 4) for a single observation; scan-able."""
    c_obs = byte_len.astype(jnp.float32) / jnp.maximum(
        prompt_tokens.astype(jnp.float32), 1.0
    )
    ratio_k = state.ratio[category]
    # first observation replaces the cold-start prior (see EmaCalibrator);
    # the SAME b drives the sigma EMA so the scalar and JAX Eq. 4 paths
    # stay in lockstep from cold start (a beta-weighted sigma here would
    # diverge whenever the prior sigma is nonzero at count=0).
    b = jnp.where(state.count[category] > 0, beta, 0.0)
    new_ratio_k = b * ratio_k + (1.0 - b) * c_obs
    dev = jnp.abs(c_obs - new_ratio_k)
    new_sigma_k = b * state.sigma[category] + (1.0 - b) * dev
    valid = prompt_tokens > 0
    return CalibState(
        ratio=state.ratio.at[category].set(
            jnp.where(valid, new_ratio_k, ratio_k)
        ),
        sigma=state.sigma.at[category].set(
            jnp.where(valid, new_sigma_k, state.sigma[category])
        ),
        count=state.count.at[category].add(jnp.where(valid, 1, 0)),
    )


@functools.partial(jax.jit, static_argnames=("beta",))
def jax_update_stream(
    state: CalibState,
    byte_lens: jax.Array,
    prompt_tokens: jax.Array,
    categories: jax.Array,
    *,
    beta: float = DEFAULT_BETA,
) -> CalibState:
    """Fold a whole observation stream through the EMA with lax.scan.

    Jitted with ``beta`` static so repeated same-shape calls (the
    fixed-chunk batches of :meth:`EmaCalibrator.observe_batch`) hit the
    compilation cache instead of retracing the scan.
    """

    def step(carry: CalibState, obs):
        b, p, k = obs
        return jax_update(carry, b, p, k, beta=beta), None

    final, _ = jax.lax.scan(step, state, (byte_lens, prompt_tokens, categories))
    return final


@functools.lru_cache(maxsize=None)
def _update_stream_kernel(chunk: int, beta: float):
    """Cached jitted EMA-stream fold, specialized per ``(chunk, beta)``.

    One compiled object per key serves every epoch / control window of a
    run (``observe_batch`` always pads to a fixed ``chunk``), so repeated
    feedback folds hit the XLA executable directly. The trace counter in
    :func:`kernel_trace_counts` proves it.
    """
    key = ("observe", chunk, beta)

    def fold(
        state: CalibState,
        byte_lens: jax.Array,
        prompt_tokens: jax.Array,
        categories: jax.Array,
    ) -> CalibState:
        _count_trace(key)  # runs at trace time only

        def step(carry: CalibState, obs):
            b, p, k = obs
            return jax_update(carry, b, p, k, beta=beta), None

        final, _ = jax.lax.scan(
            step, state, (byte_lens, prompt_tokens, categories)
        )
        return final

    return jax.jit(fold)


@functools.lru_cache(maxsize=None)
def _estimate_budget_kernel(chunk: int, gamma: float):
    """Cached jitted Eq. 3 budget estimate, specialized per ``(chunk, γ)``.

    The compiled DES backend precomputes per-request budgets on the host
    by folding the trace through ramped epochs
    (:func:`repro.sim.jax_engine.precompute_budget_trajectory`); each
    epoch pads to its ramp width and calls this kernel once instead of
    dispatching the eager estimate ops per chunk. Keyed
    ``("estimate", chunk, γ)`` in :func:`kernel_trace_counts`.
    """
    key = ("estimate", chunk, gamma)

    def kernel(
        state: CalibState,
        byte_lens: jax.Array,
        max_output_tokens: jax.Array,
        categories: jax.Array,
    ) -> jax.Array:
        _count_trace(key)  # runs at trace time only
        return jax_estimate_budget(
            state, byte_lens, max_output_tokens, categories, gamma=gamma
        )

    return jax.jit(kernel)


def jax_conservative_ratio(
    state: CalibState, *, gamma: float = DEFAULT_GAMMA
) -> jax.Array:
    """(K,) vector of ĉ_k^route = max(ĉ_k − γ σ̂_k, floor) (Eq. 5)."""
    return jnp.maximum(state.ratio - gamma * state.sigma, _MIN_RATIO)


def jax_estimate_budget(
    state: CalibState,
    byte_lens: jax.Array,
    max_output_tokens: jax.Array,
    categories: jax.Array,
    *,
    gamma: float = DEFAULT_GAMMA,
) -> jax.Array:
    """Vectorized Eq. 3 over a batch of requests → (N,) int32 L_total."""
    c_route = jax_conservative_ratio(state, gamma=gamma)[categories]
    l_in = jnp.ceil(byte_lens.astype(jnp.float32) / c_route).astype(jnp.int32)
    return l_in + max_output_tokens.astype(jnp.int32)
