"""Token-budget pool dispatch (paper §2.2, Algorithm 1), N-pool form.

The dispatch is a threshold search plus a queue-depth lookup — O(log P) over
P budget-ordered pools, O(1) for the paper's P=2. The router never needs a
tokenizer: the byte length |r| plus the calibrated per-category ratio gives
the input-token estimate, and the request's own ``max_output_tokens`` cap
gives the output term.

The paper's short/long pair is the P=2 member of a :class:`~repro.core.pools.PoolSet`
family (pools sorted by ``C_max``, thresholds ``B_1 < … < B_{P-1}``); the
two-pool constructor signature is kept as a thin compatibility layer.

Two paths:

* :class:`TokenBudgetRouter` — host-side production dispatch (scalar).
* :func:`jax_route_batch` — vectorized JAX routing of a whole request batch
  (used for trace re-simulation and the sensitivity sweeps, where millions of
  routing decisions are evaluated at once). Returns integer pool ids into the
  budget-ordered pool family.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from bisect import bisect_left
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.calibration import (
    DEFAULT_GAMMA,
    CalibState,
    EmaCalibrator,
    _count_trace,
    jax_estimate_budget,
)
from repro.core.pools import PoolSet, PoolState


@dataclasses.dataclass(frozen=True)
class Request:
    """A routing-layer view of one inference request."""

    request_id: int
    byte_len: int  # |r|: prompt byte length (observable pre-tokenization)
    max_output_tokens: int  # L_out cap from the API request
    category: int  # traffic category k
    arrival_time: float = 0.0
    # Ground truth, known only to the simulator/engine (never to the router):
    true_input_tokens: int = -1
    true_output_tokens: int = -1

    @property
    def true_total(self) -> int:
        return self.true_input_tokens + self.true_output_tokens


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    pool: str
    estimated_total: int
    spilled: bool
    conservative_ratio: float
    pool_index: int = -1  # index into the budget-ordered PoolSet


class TokenBudgetRouter:
    """Algorithm 1: token-budget pool dispatch with closed-loop calibration.

    Routes over a budget-ordered :class:`~repro.core.pools.PoolSet`: the
    static target is a threshold search, the hard constraint escalates to
    the nearest feasible pool, and load-aware spillover redirects to the
    nearest non-overloaded pool that admits the budget. The original
    two-positional-argument ``(short, long, b_short=…)`` form builds the
    equivalent P=2 PoolSet.
    """

    def __init__(
        self,
        short: Optional[PoolState] = None,
        long: Optional[PoolState] = None,
        *,
        pools: Optional[PoolSet] = None,
        b_short: int = 8192,
        calibrator: Optional[EmaCalibrator] = None,
        spillover: bool = True,
    ) -> None:
        if pools is not None:
            if short is not None or long is not None:
                raise ValueError("pass either (short, long) or pools=, not both")
            self.pools = pools
        else:
            if short is None or long is None:
                raise ValueError("need a PoolSet or a (short, long) pool pair")
            if short.config.c_max > long.config.c_max:
                raise ValueError("short pool must have the smaller C_max")
            if b_short > short.config.c_max:
                raise ValueError(
                    f"B_short={b_short} exceeds short-pool C_max={short.config.c_max}"
                )
            self.pools = PoolSet([short, long], [b_short])
        self.calibrator = calibrator or EmaCalibrator()
        self.spillover = spillover
        # Dispatch statistics (observability; §8 "monitor preemption").
        self.routed = {name: 0 for name in self.pools.names}
        self.spill_count = 0
        # Hot-path caches: the scalar dispatch must stay a few comparisons
        # (§2.2), so route() avoids attribute chains and property calls.
        # `_th` aliases the PoolSet's live threshold list — set_threshold
        # mutates it in place, so adaptive control stays visible here.
        self._th = self.pools._thresholds
        self._states = self.pools.states
        self._names = self.pools.names
        self._last = len(self._states) - 1

    # -- compatibility views --------------------------------------------------
    @property
    def short(self) -> PoolState:
        """Smallest-budget pool (P=2 compatibility view)."""
        return self.pools.states[0]

    @property
    def long(self) -> PoolState:
        """Largest-budget pool (P=2 compatibility view)."""
        return self.pools.states[-1]

    @property
    def b_short(self) -> int:
        """First routing threshold ``B_1`` (P=2 compatibility view)."""
        return int(self.pools.thresholds[0])

    @b_short.setter
    def b_short(self, value: int) -> None:
        self.pools.set_threshold(0, value)

    # -- dispatch (Algorithm 1 lines 1–14) ----------------------------------
    def route(
        self, request: Request, blocked: Optional[frozenset] = None
    ) -> RouteDecision:
        # Eq. 3/5 estimate — inlined EmaCalibrator.estimate_total_budget
        # with one ratio lookup serving both terms — then the threshold
        # search. B_k ≤ C_max,k guarantees the static target admits the
        # budget, so the escalation loop lives only in the batched-decision
        # replay (route_decided) and the spill tail.
        #
        # ``blocked`` (fault injection: tripped circuit breakers / all-down
        # pools) forces the load-dependent tail so an unhealthy target is
        # evacuated by nearest-feasible spillover.
        c_star = self.calibrator.conservative_ratio(request.category)
        l_total = (
            math.ceil(request.byte_len / c_star) + request.max_output_tokens
        )
        idx = bisect_left(self._th, l_total)
        spilled = False
        state = self._states[idx]
        # Inlined PoolState.overloaded (property calls cost ~15% of the
        # dispatch budget); _finalize re-checks it via the property.
        if (blocked is not None and idx in blocked) or (
            self.spillover
            and state.queue_depth
            > state.config.queue_limit * state.num_instances
        ):
            idx, spilled = self._finalize(idx, l_total, blocked)
        name = self._names[idx]
        self.routed[name] += 1
        return RouteDecision(name, l_total, spilled, c_star, pool_index=idx)

    def _finalize(
        self, idx: int, budget: int, blocked: Optional[frozenset] = None
    ) -> tuple[int, bool]:
        """Load-dependent tail of Algorithm 1 (lines 8–14), N-pool form.

        Hard-constraint escalation to the nearest feasible pool, then
        load-aware spillover to the nearest non-overloaded pool that admits
        the budget (so a request can never spill into a pool whose context
        window it exceeds). Pools in ``blocked`` (health-gated: open
        circuit breaker or every instance down) are treated as must-spill
        and skipped as spill targets; health evacuation applies even with
        ``spillover=False``. If no healthy pool can take the request it
        stays on the original target (degrade, don't drop).
        """
        idx = self.pools.first_feasible(idx, budget)
        unhealthy = blocked is not None and idx in blocked
        if not (
            unhealthy or (self.spillover and self.pools.states[idx].overloaded)
        ):
            return idx, False
        for k in self.pools.spill_order(idx):
            if blocked is not None and k in blocked:
                continue
            alt = self.pools.states[k]
            if not alt.overloaded and alt.config.admits(budget):
                self.spill_count += 1
                return k, True
        return idx, False

    # -- feedback (Algorithm 1 lines 15–19) ---------------------------------
    def on_response(self, request: Request, prompt_tokens: int) -> None:
        self.calibrator.observe(request.byte_len, prompt_tokens, request.category)

    def on_response_batch(self, byte_lens, prompt_tokens, categories) -> None:
        """Epoch-batched feedback: fold many responses through the EMA at
        once (vectorized fleet backend / trace re-simulation)."""
        self.calibrator.observe_batch(byte_lens, prompt_tokens, categories)

    def route_decided(
        self, pool_id: int, budget: int, blocked: Optional[frozenset] = None
    ) -> str:
        """Finalize one batched decision against live pool state.

        Replays the load-dependent tail of Algorithm 1 (hard-constraint
        escalation and spillover) for a static pool index produced by
        :meth:`route_batch`, updating the routed/spill counters exactly
        like :meth:`route`. ``blocked`` carries health-gated pool indices,
        as in :meth:`route`. Returns the target pool name.
        """
        idx, _ = self._finalize(int(pool_id), int(budget), blocked)
        name = self.pools.names[idx]
        self.routed[name] += 1
        return name

    # -- batch dispatch (vectorized fleet backend) ---------------------------
    def route_batch(self, byte_lens, max_output_tokens, categories):
        """Route a whole arrival batch with :func:`jax_route_batch`.

        Returns ``(pool_ids, budgets)`` as NumPy arrays of length
        ``len(byte_lens)``; pool ids index the budget-ordered PoolSet
        (0 = smallest budget). The static decision uses the calibrator
        state as of the call — load-dependent spillover and the
        routed/spill counters stay with the caller
        (:meth:`route_decided`), which sees live queue depths at each
        arrival's actual dispatch time.

        Inputs are padded to the next power of two so JAX compiles the
        routing kernel for a handful of shapes instead of one per ragged
        final epoch; the pad rows are sliced off *here*, before any
        caller can feed them into dispatch counters or EMA feedback.
        """
        n = len(byte_lens)
        padded = 1 << max(0, (n - 1).bit_length())
        pad = padded - n
        b = jnp.asarray(np.pad(np.asarray(byte_lens), (0, pad)), jnp.int32)
        m = jnp.asarray(
            np.pad(np.asarray(max_output_tokens), (0, pad)), jnp.int32
        )
        k = jnp.asarray(np.pad(np.asarray(categories), (0, pad)), jnp.int32)
        pools, budgets = jax_route_batch(
            self.calibrator.to_state(),
            b,
            m,
            k,
            thresholds=self.pools.thresholds,
            gamma=self.calibrator.gamma,
        )
        return np.asarray(pools)[:n], np.asarray(budgets)[:n]

    # -- observability -------------------------------------------------------
    def stats(self) -> dict:
        total = max(1, sum(self.routed.values()))
        out = {
            "routed": dict(self.routed),
            "fractions": {n: c / total for n, c in self.routed.items()},
            "spill_count": self.spill_count,
            "calibration": self.calibrator.snapshot(),
            # Live boundary vector — under adaptive control this is the
            # controller's final operating point (§8 observability).
            "thresholds": [int(b) for b in self._th],
        }
        if len(self.pools) == 2:
            first, last = self.pools.names[0], self.pools.names[-1]
            out["routed_short"] = self.routed[first]
            out["routed_long"] = self.routed[last]
            out["short_fraction"] = self.routed[first] / total
        return out


# ---------------------------------------------------------------------------
# Vectorized JAX batch routing
# ---------------------------------------------------------------------------

#: Pool ids of the paper's P=2 topology (indices into the ordered PoolSet).
SHORT, LONG = 0, 1


def jax_pool_ids(thresholds: jax.Array, budgets: jax.Array) -> jax.Array:
    """Budget → pool-index dispatch: ``searchsorted`` over ``B_1 < … <
    B_{P-1}`` (Algorithm 1's static threshold search), int32 ids into the
    budget-ordered pool family.

    The single routing decision shared by every vectorized path: the
    batch routing kernel below and the compiled DES backend's in-loop
    dispatch (:mod:`repro.sim.jax_engine`) both call it, so the device
    simulators route bit-identically to :func:`jax_route_batch`.
    """
    return jnp.searchsorted(thresholds, budgets, side="left").astype(
        jnp.int32
    )


@functools.lru_cache(maxsize=None)
def _route_batch_kernel(num_thresholds: int, dtype: str):
    """Cached jitted Eq. 3 estimate + N-way threshold search, specialized
    per ``(P, dtype)``.

    The estimate (conservative-ratio lookup, ceil-divide, output cap) and
    the ``searchsorted`` dispatch fuse into one compiled call; thresholds
    and γ are *traced* arguments, so adaptive-controller threshold moves
    and γ sweeps reuse the same executable instead of retracing per epoch.
    ``repro.core.calibration.kernel_trace_counts()`` exposes the trace
    counter keyed ``("route", P, dtype)``.
    """
    key = ("route", num_thresholds, dtype)

    def kernel(
        state: CalibState,
        byte_lens: jax.Array,
        max_output_tokens: jax.Array,
        categories: jax.Array,
        thresholds: jax.Array,
        gamma: jax.Array,
    ) -> tuple[jax.Array, jax.Array]:
        _count_trace(key)  # runs at trace time only
        budgets = jax_estimate_budget(
            state, byte_lens, max_output_tokens, categories, gamma=gamma
        )
        return jax_pool_ids(thresholds, budgets), budgets

    return jax.jit(kernel)


def jax_route_batch(
    state: CalibState,
    byte_lens: jax.Array,
    max_output_tokens: jax.Array,
    categories: jax.Array,
    *,
    thresholds: Optional[Sequence[int]] = None,
    short_cmax: int = 8192,
    b_short: int = 8192,
    gamma: float = DEFAULT_GAMMA,
) -> tuple[jax.Array, jax.Array]:
    """Route a whole batch at once. Returns (pool_ids, estimated_budgets).

    pool_ids: (N,) int32 indices into the budget-ordered pool family —
    ``searchsorted`` over ``thresholds`` (``B_1 < … < B_{P-1}``), so
    0 is the smallest pool and P-1 the largest. With the default two-pool
    ``thresholds=None`` form the ids are exactly ``SHORT``/``LONG`` and the
    boundary is ``min(b_short, short_cmax)`` (the hard constraint folds into
    the threshold because B_short ≤ short C_max). Spillover is a
    load-dependent runtime concern and is not part of the static decision.
    """
    if thresholds is None:
        thresholds = [min(b_short, short_cmax)]
    th = jnp.asarray(np.asarray(thresholds), jnp.int32)
    byte_lens = jnp.asarray(byte_lens)
    kernel = _route_batch_kernel(int(th.shape[0]), str(byte_lens.dtype))
    return kernel(
        state,
        byte_lens,
        max_output_tokens,
        categories,
        th,
        jnp.float32(gamma),
    )
