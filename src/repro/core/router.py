"""Token-budget pool dispatch (paper §2.2, Algorithm 1).

The dispatch is three comparisons and a queue-depth lookup — O(1). The
router never needs a tokenizer: the byte length |r| plus the calibrated
per-category ratio gives the input-token estimate, and the request's own
``max_output_tokens`` cap gives the output term.

Two paths:

* :class:`TokenBudgetRouter` — host-side production dispatch (scalar, O(1)).
* :func:`jax_route_batch` — vectorized JAX routing of a whole request batch
  (used for trace re-simulation and the sensitivity sweeps, where millions of
  routing decisions are evaluated at once).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.calibration import (
    DEFAULT_GAMMA,
    CalibState,
    EmaCalibrator,
    jax_estimate_budget,
)
from repro.core.pools import PoolConfig, PoolState, validate_pools


@dataclasses.dataclass(frozen=True)
class Request:
    """A routing-layer view of one inference request."""

    request_id: int
    byte_len: int  # |r|: prompt byte length (observable pre-tokenization)
    max_output_tokens: int  # L_out cap from the API request
    category: int  # traffic category k
    arrival_time: float = 0.0
    # Ground truth, known only to the simulator/engine (never to the router):
    true_input_tokens: int = -1
    true_output_tokens: int = -1

    @property
    def true_total(self) -> int:
        return self.true_input_tokens + self.true_output_tokens


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    pool: str
    estimated_total: int
    spilled: bool
    conservative_ratio: float


class TokenBudgetRouter:
    """Algorithm 1: token-budget pool dispatch with closed-loop calibration."""

    def __init__(
        self,
        short: PoolState,
        long: PoolState,
        *,
        b_short: int = 8192,
        calibrator: Optional[EmaCalibrator] = None,
        spillover: bool = True,
    ) -> None:
        validate_pools([short.config, long.config])
        if short.config.c_max > long.config.c_max:
            raise ValueError("short pool must have the smaller C_max")
        if b_short > short.config.c_max:
            raise ValueError(
                f"B_short={b_short} exceeds short-pool C_max={short.config.c_max}"
            )
        self.short = short
        self.long = long
        self.b_short = b_short
        self.calibrator = calibrator or EmaCalibrator()
        self.spillover = spillover
        # Dispatch statistics (observability; §8 "monitor preemption").
        self.routed = {"short": 0, "long": 0}
        self.spill_count = 0

    # -- dispatch (Algorithm 1 lines 1–14) ----------------------------------
    def route(self, request: Request) -> RouteDecision:
        c_star = self.calibrator.conservative_ratio(request.category)
        l_total = self.calibrator.estimate_total_budget(
            request.byte_len, request.max_output_tokens, request.category
        )

        # Hard constraint: exceeds short pool capacity → long pool, no spill.
        if not self.short.config.admits(l_total):
            self.routed["long"] += 1
            return RouteDecision("long", l_total, False, c_star)

        # Budget-based dispatch.
        target, alternate = (
            (self.short, self.long)
            if l_total <= self.b_short
            else (self.long, self.short)
        )

        # Load-aware spillover: redirect when the target is overloaded and
        # the alternate can serve the request (hard constraint re-checked).
        spilled = False
        if (
            self.spillover
            and target.overloaded
            and not alternate.overloaded
            and alternate.config.admits(l_total)
        ):
            target = alternate
            spilled = True
            self.spill_count += 1

        self.routed[target.config.name] += 1
        return RouteDecision(target.config.name, l_total, spilled, c_star)

    # -- feedback (Algorithm 1 lines 15–19) ---------------------------------
    def on_response(self, request: Request, prompt_tokens: int) -> None:
        self.calibrator.observe(request.byte_len, prompt_tokens, request.category)

    def on_response_batch(self, byte_lens, prompt_tokens, categories) -> None:
        """Epoch-batched feedback: fold many responses through the EMA at
        once (vectorized fleet backend / trace re-simulation)."""
        self.calibrator.observe_batch(byte_lens, prompt_tokens, categories)

    def route_decided(self, pool_id: int, budget: int) -> str:
        """Finalize one batched decision against live pool state.

        Replays the load-dependent tail of Algorithm 1 (hard-constraint
        override and spillover, lines 8–14) for a static short/long choice
        produced by :meth:`route_batch`, updating the routed/spill counters
        exactly like :meth:`route`. Returns the target pool name.
        """
        if not self.short.config.admits(budget):
            # Beyond short C_max → long pool, no spill (as in route()).
            self.routed["long"] += 1
            return "long"
        target, alternate = (
            (self.short, self.long)
            if pool_id == SHORT
            else (self.long, self.short)
        )
        if (
            self.spillover
            and target.overloaded
            and not alternate.overloaded
            and alternate.config.admits(budget)
        ):
            target = alternate
            self.spill_count += 1
        name = target.config.name
        self.routed[name] += 1
        return name

    # -- batch dispatch (vectorized fleet backend) ---------------------------
    def route_batch(self, byte_lens, max_output_tokens, categories):
        """Route a whole arrival batch with :func:`jax_route_batch`.

        Returns ``(pool_ids, budgets)`` as NumPy arrays (0=short, 1=long).
        The static decision uses the calibrator state as of the call —
        load-dependent spillover and the routed/spill counters stay with the
        caller, which sees live queue depths at each arrival's actual
        dispatch time.
        """
        n = len(byte_lens)
        # Pad to the next power of two so JAX compiles the routing kernel
        # for a handful of shapes instead of one per ragged final epoch.
        padded = 1 << max(0, (n - 1).bit_length())
        pad = padded - n
        b = jnp.asarray(np.pad(np.asarray(byte_lens), (0, pad)), jnp.int32)
        m = jnp.asarray(
            np.pad(np.asarray(max_output_tokens), (0, pad)), jnp.int32
        )
        k = jnp.asarray(np.pad(np.asarray(categories), (0, pad)), jnp.int32)
        pools, budgets = jax_route_batch(
            self.calibrator.to_state(),
            b,
            m,
            k,
            short_cmax=self.short.config.c_max,
            b_short=self.b_short,
            gamma=self.calibrator.gamma,
        )
        return np.asarray(pools)[:n], np.asarray(budgets)[:n]

    # -- observability -------------------------------------------------------
    def stats(self) -> dict:
        total = max(1, self.routed["short"] + self.routed["long"])
        return {
            "routed_short": self.routed["short"],
            "routed_long": self.routed["long"],
            "short_fraction": self.routed["short"] / total,
            "spill_count": self.spill_count,
            "calibration": self.calibrator.snapshot(),
        }


# ---------------------------------------------------------------------------
# Vectorized JAX batch routing
# ---------------------------------------------------------------------------

SHORT, LONG = 0, 1


@jax.jit
def _route_kernel(
    budgets: jax.Array,
    short_cmax: jax.Array,
    b_short: jax.Array,
) -> jax.Array:
    exceeds = budgets > short_cmax
    long_budget = budgets > b_short
    return jnp.where(exceeds | long_budget, LONG, SHORT).astype(jnp.int32)


def jax_route_batch(
    state: CalibState,
    byte_lens: jax.Array,
    max_output_tokens: jax.Array,
    categories: jax.Array,
    *,
    short_cmax: int = 8192,
    b_short: int = 8192,
    gamma: float = DEFAULT_GAMMA,
) -> tuple[jax.Array, jax.Array]:
    """Route a whole batch at once. Returns (pool_ids, estimated_budgets).

    pool_ids: (N,) int32 with 0=short, 1=long. Spillover is a load-dependent
    runtime concern and is not part of the static batch decision.
    """
    budgets = jax_estimate_budget(
        state, byte_lens, max_output_tokens, categories, gamma=gamma
    )
    pools = _route_kernel(
        budgets, jnp.int32(short_cmax), jnp.int32(b_short)
    )
    return pools, budgets
