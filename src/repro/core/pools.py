"""Pool configuration and fleet sizing (paper §2, §3, Table 1).

A *pool* is a set of identically-configured serving instances. The two-pool
design (paper §8: "start with two pools") is the default, but the types below
support N pools so the three-pool ablation can be expressed.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

#: vLLM-style fixed KV block size in tokens (paper §3, effect 3 / Appendix A).
KV_BLOCK_TOKENS = 16

#: Total KV block budget per instance used by the paper's dynamic pool
#: configuration (Appendix A): N_seq = min(128, floor(65536 / ceil(C_max/16))).
TOTAL_KV_BLOCKS = 65_536


def n_seq_for_cmax(
    c_max: int, *, max_slots: int = 128, total_blocks: int = TOTAL_KV_BLOCKS
) -> int:
    """Sequence slots for a given C_max under the fixed block budget.

    Paper Appendix A: ``N_seq = min(128, floor(65536 / ceil(B_short/16)))``.
    ``total_blocks`` scales with KV bytes/token (int8 KV doubles it).
    """
    blocks_per_seq = math.ceil(c_max / KV_BLOCK_TOKENS)
    return max(1, min(max_slots, total_blocks // blocks_per_seq))


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    """Static configuration of one pool."""

    name: str
    c_max: int  # max_model_len for every instance in the pool
    n_seq: int  # concurrent sequence slots per instance
    batch_token_budget: int = 8192  # B_batch: max batched tokens per iteration
    queue_limit: int = 256  # spillover trigger: pending requests per instance
    headroom: float = 1.05  # β queuing-headroom factor for fleet sizing

    def admits(self, l_total: int) -> bool:
        """Hard constraint: can this pool ever serve a request of L_total?"""
        return l_total <= self.c_max


def short_pool(
    c_max: int = 8192, *, name: str = "short", headroom: float = 1.05
) -> PoolConfig:
    """The high-throughput short pool P_s (Table 1 row 2)."""
    return PoolConfig(
        name=name,
        c_max=c_max,
        n_seq=n_seq_for_cmax(c_max),
        batch_token_budget=16_384,
        headroom=headroom,
    )


def long_pool(
    c_max: int = 65_536, *, name: str = "long", headroom: float = 1.02
) -> PoolConfig:
    """The high-capacity long pool P_l (Table 1 row 3)."""
    return PoolConfig(
        name=name,
        c_max=c_max,
        n_seq=n_seq_for_cmax(c_max, max_slots=16),
        batch_token_budget=8192,
        headroom=headroom,
    )


def homogeneous_pool(c_max: int = 65_536, *, headroom: float = 1.08) -> PoolConfig:
    """Baseline: every instance provisioned for the worst case (Table 1 row 1)."""
    return PoolConfig(
        name="homogeneous",
        c_max=c_max,
        n_seq=n_seq_for_cmax(c_max, max_slots=16),
        batch_token_budget=8192,
        headroom=headroom,
    )


@dataclasses.dataclass
class PoolState:
    """Mutable per-pool dispatch state visible to the router (O(1) reads)."""

    config: PoolConfig
    num_instances: int = 1
    queue_depth: int = 0  # requests waiting across the pool
    active: int = 0  # requests currently being served

    @property
    def overloaded(self) -> bool:
        return self.queue_depth > self.config.queue_limit * self.num_instances

    @property
    def utilization_slots(self) -> float:
        cap = max(1, self.num_instances * self.config.n_seq)
        return self.active / cap


def fleet_instances(
    rate: float, mu_per_instance: float, headroom: float = 1.0
) -> int:
    """ceil(λ/μ × β) — analytical fleet size (paper Appendix A)."""
    if mu_per_instance <= 0:
        raise ValueError("throughput must be positive")
    return max(1, math.ceil(rate / mu_per_instance * headroom))


def dual_pool_fleet(
    rate: float,
    alpha: float,
    mu_short: float,
    mu_long: float,
    *,
    headroom_short: float = 1.05,
    headroom_long: float = 1.02,
) -> tuple[int, int]:
    """Corrected fleet formula (Eq. 8): G = αλ/μ_Ps + (1−α)λ/μ_Pl.

    Returns (short_instances, long_instances); either may be 0 when its
    traffic share is 0.
    """
    short = (
        fleet_instances(alpha * rate, mu_short, headroom_short) if alpha > 0 else 0
    )
    long_ = (
        fleet_instances((1.0 - alpha) * rate, mu_long, headroom_long)
        if alpha < 1.0
        else 0
    )
    return short, long_


def validate_pools(pools: Sequence[PoolConfig]) -> None:
    """Sanity checks shared by router and simulator."""
    if not pools:
        raise ValueError("need at least one pool")
    names = [p.name for p in pools]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate pool names: {names}")
    for p in pools:
        if p.c_max <= 0 or p.n_seq <= 0:
            raise ValueError(f"pool {p.name} has non-positive capacity")
