"""Pool configuration and fleet sizing (paper §2, §3, Table 1).

A *pool* is a set of identically-configured serving instances. The paper's
two-pool design (§8: "start with two pools") is the P=2 member of the
budget-ordered pool family modelled by :class:`PoolSet`: P pools sorted by
``C_max`` with routing thresholds ``B_1 < … < B_{P-1}``. The router, both
simulator backends, and the three-pool ablation all operate on a PoolSet.
"""

from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Sequence

import numpy as np

#: vLLM-style fixed KV block size in tokens (paper §3, effect 3 / Appendix A).
KV_BLOCK_TOKENS = 16

#: Total KV block budget per instance used by the paper's dynamic pool
#: configuration (Appendix A): N_seq = min(128, floor(65536 / ceil(C_max/16))).
TOTAL_KV_BLOCKS = 65_536


def n_seq_for_cmax(
    c_max: int, *, max_slots: int = 128, total_blocks: int = TOTAL_KV_BLOCKS
) -> int:
    """Sequence slots for a given C_max under the fixed block budget.

    Paper Appendix A: ``N_seq = min(128, floor(65536 / ceil(B_short/16)))``.
    ``total_blocks`` scales with KV bytes/token (int8 KV doubles it).
    """
    blocks_per_seq = math.ceil(c_max / KV_BLOCK_TOKENS)
    return max(1, min(max_slots, total_blocks // blocks_per_seq))


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    """Static configuration of one pool."""

    name: str
    c_max: int  # max_model_len for every instance in the pool
    n_seq: int  # concurrent sequence slots per instance
    batch_token_budget: int = 8192  # B_batch: max batched tokens per iteration
    queue_limit: int = 256  # spillover trigger: pending requests per instance
    headroom: float = 1.05  # β queuing-headroom factor for fleet sizing

    def admits(self, l_total: int) -> bool:
        """Hard constraint: can this pool ever serve a request of L_total?"""
        return l_total <= self.c_max


def short_pool(
    c_max: int = 8192, *, name: str = "short", headroom: float = 1.05
) -> PoolConfig:
    """The high-throughput short pool P_s (Table 1 row 2)."""
    return PoolConfig(
        name=name,
        c_max=c_max,
        n_seq=n_seq_for_cmax(c_max),
        batch_token_budget=16_384,
        headroom=headroom,
    )


def long_pool(
    c_max: int = 65_536, *, name: str = "long", headroom: float = 1.02
) -> PoolConfig:
    """The high-capacity long pool P_l (Table 1 row 3)."""
    return PoolConfig(
        name=name,
        c_max=c_max,
        n_seq=n_seq_for_cmax(c_max, max_slots=16),
        batch_token_budget=8192,
        headroom=headroom,
    )


def homogeneous_pool(c_max: int = 65_536, *, headroom: float = 1.08) -> PoolConfig:
    """Baseline: every instance provisioned for the worst case (Table 1 row 1)."""
    return PoolConfig(
        name="homogeneous",
        c_max=c_max,
        n_seq=n_seq_for_cmax(c_max, max_slots=16),
        batch_token_budget=8192,
        headroom=headroom,
    )


@dataclasses.dataclass
class PoolState:
    """Mutable per-pool dispatch state visible to the router (O(1) reads)."""

    config: PoolConfig
    num_instances: int = 1
    queue_depth: int = 0  # requests waiting across the pool
    active: int = 0  # requests currently being served

    @property
    def overloaded(self) -> bool:
        # Inlined in TokenBudgetRouter.route()'s spill pre-check (the
        # sub-µs dispatch path) — change both together.
        return self.queue_depth > self.config.queue_limit * self.num_instances

    @property
    def utilization_slots(self) -> float:
        cap = max(1, self.num_instances * self.config.n_seq)
        return self.active / cap


class PoolSet:
    """Budget-ordered pools ``P_1 … P_P`` with thresholds ``B_1 < … < B_{P-1}``.

    The routing rule of Algorithm 1, generalized to N pools: a request with
    estimated budget ``L`` statically targets the first pool ``k`` with
    ``L ≤ B_k`` (the last pool when ``L`` exceeds every threshold). Each
    threshold is bounded by its pool's context window (``B_k ≤ C_max,k``),
    so a static target below the last pool always admits the request.

    Pools are sorted by ``C_max`` at construction (stable, so equal-capacity
    pools keep caller order); ``thresholds`` stays a mutable array because
    the adaptive controller moves boundaries at runtime
    (:class:`repro.core.adaptive.AdaptiveController`).
    """

    def __init__(
        self, states: Sequence["PoolState"], thresholds: Sequence[int]
    ) -> None:
        states = list(states)
        validate_pools([s.config for s in states])
        order = sorted(range(len(states)), key=lambda i: states[i].config.c_max)
        self.states: list[PoolState] = [states[i] for i in order]
        self.configs: list[PoolConfig] = [s.config for s in self.states]
        self.names: list[str] = [c.name for c in self.configs]
        if len(thresholds) != len(states) - 1:
            raise ValueError(
                f"{len(states)} pools need {len(states) - 1} thresholds, "
                f"got {len(thresholds)}"
            )
        # Plain int list for the O(1)/O(log P) scalar dispatch hot path
        # (bisect beats an np.searchsorted call by ~5× per request);
        # `thresholds` exposes the same values as an array for the batch
        # kernel and stays the mutation point for adaptive control.
        self._thresholds = [int(b) for b in thresholds]
        self._validate_thresholds()
        # Spillover candidate order per target pool, precomputed: by
        # distance from the target, larger-capacity neighbour preferred on
        # ties — the safer direction under the paper's asymmetric error
        # costs.
        p = len(self.states)
        self._spill_orders = [
            sorted(
                (k for k in range(p) if k != idx),
                key=lambda k: (abs(k - idx), -k),
            )
            for idx in range(p)
        ]

    def _validate_thresholds(self) -> None:
        th = self._thresholds
        if th and th[0] <= 0:
            raise ValueError(f"thresholds must be positive: {th}")
        if any(nxt <= prev for nxt, prev in zip(th[1:], th)):
            raise ValueError(f"thresholds must be strictly increasing: {th}")
        for k, b in enumerate(th):
            if b > self.configs[k].c_max:
                raise ValueError(
                    f"B_{k + 1}={b} exceeds pool "
                    f"{self.names[k]!r} C_max={self.configs[k].c_max}"
                )

    def __len__(self) -> int:
        return len(self.states)

    @property
    def thresholds(self) -> np.ndarray:
        """(P-1,) int64 boundaries, for the vectorized routing kernel."""
        return np.asarray(self._thresholds, dtype=np.int64)

    def set_threshold(self, k: int, value: int) -> None:
        """Move one boundary (adaptive control), re-validating the order."""
        old = self._thresholds[k]
        self._thresholds[k] = int(value)
        try:
            self._validate_thresholds()
        except ValueError:
            self._thresholds[k] = old
            raise

    def set_thresholds(self, values: Sequence[int]) -> None:
        """Replace the whole boundary vector atomically (adaptive control).

        Mutates the threshold list *in place* so live aliases (the router's
        hot-path view) observe the move; restores the previous vector when
        validation fails, so observers never see an invalid ordering.
        """
        if len(values) != len(self._thresholds):
            raise ValueError(
                f"expected {len(self._thresholds)} thresholds, got {len(values)}"
            )
        old = list(self._thresholds)
        self._thresholds[:] = [int(v) for v in values]
        try:
            self._validate_thresholds()
        except ValueError:
            self._thresholds[:] = old
            raise

    def static_pool(self, budget: int) -> int:
        """Threshold search: first pool index whose ``B_k`` covers ``budget``."""
        return bisect.bisect_left(self._thresholds, budget)

    def first_feasible(self, idx: int, budget: int) -> int:
        """Hard-constraint escalation: the nearest pool at or above ``idx``
        that admits ``budget`` (the last pool when none does)."""
        last = len(self.states) - 1
        while idx < last and not self.configs[idx].admits(budget):
            idx += 1
        return idx

    def spill_order(self, idx: int) -> list[int]:
        """Spillover candidates for a request targeting pool ``idx``."""
        return self._spill_orders[idx]


def fleet_instances(
    rate: float, mu_per_instance: float, headroom: float = 1.0
) -> int:
    """ceil(λ/μ × β) — analytical fleet size (paper Appendix A)."""
    if mu_per_instance <= 0:
        raise ValueError("throughput must be positive")
    return max(1, math.ceil(rate / mu_per_instance * headroom))


def dual_pool_fleet(
    rate: float,
    alpha: float,
    mu_short: float,
    mu_long: float,
    *,
    headroom_short: float = 1.05,
    headroom_long: float = 1.02,
) -> tuple[int, int]:
    """Corrected fleet formula (Eq. 8): G = αλ/μ_Ps + (1−α)λ/μ_Pl.

    Returns (short_instances, long_instances); either may be 0 when its
    traffic share is 0.
    """
    short = (
        fleet_instances(alpha * rate, mu_short, headroom_short) if alpha > 0 else 0
    )
    long_ = (
        fleet_instances((1.0 - alpha) * rate, mu_long, headroom_long)
        if alpha < 1.0
        else 0
    )
    return short, long_


def validate_pools(pools: Sequence[PoolConfig]) -> None:
    """Sanity checks shared by router and simulator."""
    if not pools:
        raise ValueError("need at least one pool")
    names = [p.name for p in pools]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate pool names: {names}")
    for p in pools:
        if p.c_max <= 0 or p.n_seq <= 0:
            raise ValueError(f"pool {p.name} has non-positive capacity")
